//! Window-polynomial optimization — the paper's §VI closing remark
//! ("we choose the window selection distributions arbitrarily … this
//! distribution can be optimized to minimize the loss"), implemented as
//! projected coordinate descent on the Theorem 2/3 objective.

use super::theorems::{TheoremLoss, UepStrategy};

/// Result of a window-polynomial optimization.
#[derive(Clone, Debug)]
pub struct GammaOpt {
    /// Optimized window probabilities (simplex point).
    pub gamma: Vec<f64>,
    /// Objective value (expected normalized loss at the target deadline).
    pub loss: f64,
    /// Loss of the starting polynomial, for comparison.
    pub initial_loss: f64,
    pub iterations: usize,
}

/// Minimize `E[L(t*)]/E‖C‖²` over the probability simplex by cyclic
/// pairwise mass transfer: repeatedly move probability mass between two
/// windows if it lowers the objective (exact line search by trisection
/// on each pair). The objective is piecewise-smooth and low-dimensional
/// (L ≤ 5 in all paper setups), so this simple scheme converges to the
/// simplex-constrained optimum in a handful of sweeps.
pub fn optimize_gamma(
    base: &TheoremLoss,
    strategy: UepStrategy,
    t_star: f64,
    max_sweeps: usize,
) -> GammaOpt {
    let l = base.gamma.len();
    let eval = |gamma: &[f64]| -> f64 {
        let mut th = base.clone();
        th.gamma = gamma.to_vec();
        th.normalized_loss(strategy, t_star)
    };
    let mut gamma = base.gamma.clone();
    let initial_loss = eval(&gamma);
    let mut best = initial_loss;
    let mut iterations = 0;
    for _ in 0..max_sweeps {
        let mut improved = false;
        for i in 0..l {
            for j in 0..l {
                if i == j {
                    continue;
                }
                // transfer δ ∈ [0, gamma[j]] from window j to window i;
                // golden-section search over δ
                let (mut lo, mut hi) = (0.0, gamma[j]);
                if hi < 1e-6 {
                    continue;
                }
                let phi = 0.618_033_988_75;
                let mut x1 = hi - phi * (hi - lo);
                let mut x2 = lo + phi * (hi - lo);
                let try_delta = |d: f64, gamma: &[f64]| {
                    let mut g = gamma.to_vec();
                    g[i] += d;
                    g[j] -= d;
                    eval(&g)
                };
                let mut f1 = try_delta(x1, &gamma);
                let mut f2 = try_delta(x2, &gamma);
                for _ in 0..24 {
                    if f1 < f2 {
                        hi = x2;
                        x2 = x1;
                        f2 = f1;
                        x1 = hi - phi * (hi - lo);
                        f1 = try_delta(x1, &gamma);
                    } else {
                        lo = x1;
                        x1 = x2;
                        f1 = f2;
                        x2 = lo + phi * (hi - lo);
                        f2 = try_delta(x2, &gamma);
                    }
                    iterations += 1;
                }
                let d = 0.5 * (lo + hi);
                let f = try_delta(d, &gamma);
                if f < best - 1e-9 {
                    gamma[i] += d;
                    gamma[j] -= d;
                    best = f;
                    improved = true;
                }
            }
        }
        if !improved {
            break;
        }
    }
    GammaOpt { gamma, loss: best, initial_loss, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::LatencyModel;
    use crate::util::prop::{gen, prop_check, PropConfig};

    fn base() -> TheoremLoss {
        TheoremLoss {
            u: 50,
            h: 150,
            q: 50,
            k: vec![3, 3, 3],
            sigma2: vec![40.0, 1.0, 0.07],
            gamma: vec![0.40, 0.35, 0.25],
            workers: 30,
            latency: LatencyModel::exp(1.0),
            omega: 0.3,
            cxr_bound_factor: 1,
        }
    }

    #[test]
    fn optimizer_improves_on_paper_gamma() {
        let th = base();
        let opt = optimize_gamma(&th, UepStrategy::Ew, 0.5, 6);
        assert!(
            opt.loss < opt.initial_loss - 1e-3,
            "no improvement: {} vs {}",
            opt.loss,
            opt.initial_loss
        );
        // result stays on the simplex
        let s: f64 = opt.gamma.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(opt.gamma.iter().all(|&g| g >= -1e-12));
        // with class 1 holding ~97% of the energy, the optimum shifts
        // mass toward window 1
        assert!(
            opt.gamma[0] > 0.40,
            "expected Γ₁ to grow, got {:?}",
            opt.gamma
        );
    }

    /// Property: across random configurations (window count, class
    /// sizes, energies, starting polynomial, deadline, strategy) the
    /// optimizer's result always stays on the probability simplex and
    /// never does worse than its starting point.
    #[test]
    fn prop_result_on_simplex_and_never_worse_than_start() {
        prop_check(
            "gamma_opt simplex + improvement",
            PropConfig { cases: 16, ..Default::default() },
            |rng, _case| {
                let l = gen::usize_in(rng, 2, 3);
                let th = TheoremLoss {
                    u: gen::usize_in(rng, 2, 20),
                    h: gen::usize_in(rng, 2, 40),
                    q: gen::usize_in(rng, 2, 20),
                    k: (0..l).map(|_| gen::usize_in(rng, 1, 3)).collect(),
                    sigma2: (0..l).map(|_| gen::f64_in(rng, 0.01, 50.0)).collect(),
                    gamma: gen::simplex(rng, l),
                    workers: gen::usize_in(rng, 4, 16),
                    latency: LatencyModel::exp(gen::f64_in(rng, 0.2, 3.0)),
                    omega: gen::f64_in(rng, 0.2, 1.5),
                    cxr_bound_factor: 1,
                };
                let strategy = if gen::usize_in(rng, 0, 1) == 0 {
                    UepStrategy::Now
                } else {
                    UepStrategy::Ew
                };
                let t_star = gen::f64_in(rng, 0.1, 2.0);
                let opt = optimize_gamma(&th, strategy, t_star, 3);
                let sum: f64 = opt.gamma.iter().sum();
                if (sum - 1.0).abs() > 1e-9 {
                    return Err(format!("left the simplex: sum {sum}"));
                }
                if let Some(&g) = opt.gamma.iter().find(|&&g| g < -1e-12) {
                    return Err(format!("negative probability {g}"));
                }
                if opt.loss > opt.initial_loss + 1e-9 {
                    return Err(format!(
                        "worse than start: {} > {} ({strategy:?}, t*={t_star})",
                        opt.loss, opt.initial_loss
                    ));
                }
                Ok(())
            },
        );
    }

    /// Hand-computable 2-window instance: under NOW coding a window only
    /// ever decodes its own class, and here class 1 carries zero energy
    /// (σ² = 0) — so any mass spent on window 1 is provably wasted and
    /// the unique optimum is Γ = (1, 0). The optimizer must find it from
    /// a start that wastes most of its mass.
    #[test]
    fn recovers_known_two_window_optimum() {
        let th = TheoremLoss {
            u: 4,
            h: 8,
            q: 4,
            k: vec![2, 2],
            sigma2: vec![1.0, 0.0],
            gamma: vec![0.2, 0.8],
            workers: 12,
            latency: LatencyModel::exp(1.0),
            omega: 4.0 / 12.0,
            cxr_bound_factor: 1,
        };
        let opt = optimize_gamma(&th, UepStrategy::Now, 0.8, 8);
        assert!(
            opt.gamma[0] > 0.999,
            "optimum is Γ = (1, 0), got {:?}",
            opt.gamma
        );
        assert!(opt.loss <= opt.initial_loss);
        // and the found optimum matches the closed-form value: only
        // class 0 contributes, with decode probability P[Bin(w, 1) ≥ 2]
        // marginalized over arrivals
        let best = th.with_gamma(vec![1.0, 0.0]).normalized_loss(UepStrategy::Now, 0.8);
        assert!(
            (opt.loss - best).abs() < 1e-5,
            "found {} vs closed-form optimum {best}",
            opt.loss
        );
    }

    #[test]
    fn optimum_is_stable_under_restart() {
        let th = base();
        let a = optimize_gamma(&th, UepStrategy::Now, 0.8, 6);
        let mut th2 = th.clone();
        th2.gamma = a.gamma.clone();
        let b = optimize_gamma(&th2, UepStrategy::Now, 0.8, 6);
        assert!(b.loss <= a.loss + 1e-9);
        assert!((b.loss - a.loss).abs() < 1e-3, "restart moved: {} vs {}", a.loss, b.loss);
    }
}
