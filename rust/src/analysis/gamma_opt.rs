//! Window-polynomial optimization — the paper's §VI closing remark
//! ("we choose the window selection distributions arbitrarily … this
//! distribution can be optimized to minimize the loss"), implemented as
//! projected coordinate descent on the Theorem 2/3 objective.

use super::theorems::{TheoremLoss, UepStrategy};

/// Result of a window-polynomial optimization.
#[derive(Clone, Debug)]
pub struct GammaOpt {
    /// Optimized window probabilities (simplex point).
    pub gamma: Vec<f64>,
    /// Objective value (expected normalized loss at the target deadline).
    pub loss: f64,
    /// Loss of the starting polynomial, for comparison.
    pub initial_loss: f64,
    pub iterations: usize,
}

/// Minimize `E[L(t*)]/E‖C‖²` over the probability simplex by cyclic
/// pairwise mass transfer: repeatedly move probability mass between two
/// windows if it lowers the objective (exact line search by trisection
/// on each pair). The objective is piecewise-smooth and low-dimensional
/// (L ≤ 5 in all paper setups), so this simple scheme converges to the
/// simplex-constrained optimum in a handful of sweeps.
pub fn optimize_gamma(
    base: &TheoremLoss,
    strategy: UepStrategy,
    t_star: f64,
    max_sweeps: usize,
) -> GammaOpt {
    let l = base.gamma.len();
    let eval = |gamma: &[f64]| -> f64 {
        let mut th = base.clone();
        th.gamma = gamma.to_vec();
        th.normalized_loss(strategy, t_star)
    };
    let mut gamma = base.gamma.clone();
    let initial_loss = eval(&gamma);
    let mut best = initial_loss;
    let mut iterations = 0;
    for _ in 0..max_sweeps {
        let mut improved = false;
        for i in 0..l {
            for j in 0..l {
                if i == j {
                    continue;
                }
                // transfer δ ∈ [0, gamma[j]] from window j to window i;
                // golden-section search over δ
                let (mut lo, mut hi) = (0.0, gamma[j]);
                if hi < 1e-6 {
                    continue;
                }
                let phi = 0.618_033_988_75;
                let mut x1 = hi - phi * (hi - lo);
                let mut x2 = lo + phi * (hi - lo);
                let try_delta = |d: f64, gamma: &[f64]| {
                    let mut g = gamma.to_vec();
                    g[i] += d;
                    g[j] -= d;
                    eval(&g)
                };
                let mut f1 = try_delta(x1, &gamma);
                let mut f2 = try_delta(x2, &gamma);
                for _ in 0..24 {
                    if f1 < f2 {
                        hi = x2;
                        x2 = x1;
                        f2 = f1;
                        x1 = hi - phi * (hi - lo);
                        f1 = try_delta(x1, &gamma);
                    } else {
                        lo = x1;
                        x1 = x2;
                        f1 = f2;
                        x2 = lo + phi * (hi - lo);
                        f2 = try_delta(x2, &gamma);
                    }
                    iterations += 1;
                }
                let d = 0.5 * (lo + hi);
                let f = try_delta(d, &gamma);
                if f < best - 1e-9 {
                    gamma[i] += d;
                    gamma[j] -= d;
                    best = f;
                    improved = true;
                }
            }
        }
        if !improved {
            break;
        }
    }
    GammaOpt { gamma, loss: best, initial_loss, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::LatencyModel;

    fn base() -> TheoremLoss {
        TheoremLoss {
            u: 50,
            h: 150,
            q: 50,
            k: vec![3, 3, 3],
            sigma2: vec![40.0, 1.0, 0.07],
            gamma: vec![0.40, 0.35, 0.25],
            workers: 30,
            latency: LatencyModel::exp(1.0),
            omega: 0.3,
            cxr_bound_factor: 1,
        }
    }

    #[test]
    fn optimizer_improves_on_paper_gamma() {
        let th = base();
        let opt = optimize_gamma(&th, UepStrategy::Ew, 0.5, 6);
        assert!(
            opt.loss < opt.initial_loss - 1e-3,
            "no improvement: {} vs {}",
            opt.loss,
            opt.initial_loss
        );
        // result stays on the simplex
        let s: f64 = opt.gamma.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(opt.gamma.iter().all(|&g| g >= -1e-12));
        // with class 1 holding ~97% of the energy, the optimum shifts
        // mass toward window 1
        assert!(
            opt.gamma[0] > 0.40,
            "expected Γ₁ to grow, got {:?}",
            opt.gamma
        );
    }

    #[test]
    fn optimum_is_stable_under_restart() {
        let th = base();
        let a = optimize_gamma(&th, UepStrategy::Now, 0.8, 6);
        let mut th2 = th.clone();
        th2.gamma = a.gamma.clone();
        let b = optimize_gamma(&th2, UepStrategy::Now, 0.8, 6);
        assert!(b.loss <= a.loss + 1e-9);
        assert!((b.loss - a.loss).abs() < 1e-3, "restart moved: {} vs {}", a.loss, b.loss);
    }
}
