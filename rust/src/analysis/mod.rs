//! Analytic performance characterization (paper §V + [19]):
//! arrival statistics (eq. 19), NOW/EW decoding probabilities
//! (eqs. 20–21 and [19, eqs. 5–9]), the Theorem 2/3 expected-loss
//! formulas, and closed-form baseline curves for MDS / repetition /
//! uncoded computation.

mod combinatorics;
mod decoding_prob;
mod gamma_opt;
mod theorems;

pub use combinatorics::{binomial_pmf, compositions, ln_binomial, multinomial_pmf};
pub use gamma_opt::{optimize_gamma, GammaOpt};
pub use decoding_prob::{
    ew_decodable_levels, ew_decode_prob, ew_prefix_solvable, now_decode_prob,
};
pub use theorems::{
    mds_loss_vs_packets, mds_loss_vs_time, repetition_loss_vs_packets,
    repetition_loss_vs_time, TheoremLoss, UepStrategy,
};
