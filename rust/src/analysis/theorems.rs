//! Expected-loss formulas: Theorem 2 (r×c) and Theorem 3 (c×r upper
//! bound) for NOW/EW-UEP, plus closed-form MDS / repetition / uncoded
//! reference curves under Assumption 1.

use crate::latency::LatencyModel;

use super::combinatorics::{binomial_pmf, ln_binomial};
use super::decoding_prob::{ew_decode_prob, now_decode_prob};

/// Which UEP window strategy a formula evaluates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UepStrategy {
    Now,
    Ew,
}

/// Inputs of Theorems 2/3 for one experimental configuration.
#[derive(Clone, Debug)]
pub struct TheoremLoss {
    /// Sub-block dims: each sub-product is `U×Q` with inner dim `H`.
    pub u: usize,
    pub h: usize,
    pub q: usize,
    /// `k_l`: sub-products per importance class of `C`.
    pub k: Vec<usize>,
    /// Per-class variance products `σ²_{l,A}·σ²_{l,B}`.
    pub sigma2: Vec<f64>,
    /// Window selection probabilities `Γ_l`.
    pub gamma: Vec<f64>,
    /// Number of workers `W`.
    pub workers: usize,
    /// Latency model `F`.
    pub latency: LatencyModel,
    /// Time scaling `Ω` (Remark 1).
    pub omega: f64,
    /// `M` prefactor of the Theorem 3 c×r bound (1 for r×c).
    pub cxr_bound_factor: usize,
}

impl TheoremLoss {
    /// Assemble the Theorem 2/3 inputs from a *live* plan: partition
    /// geometry, the importance classification actually in force, the
    /// (estimated) per-class variance products, and a latency model —
    /// typically one fitted from observed timings by a
    /// [`crate::latency::LatencyEstimator`]. This is the bridge the
    /// adaptive replanner ([`crate::api::Replanner`]) crosses from
    /// telemetry to the `optimize_gamma` objective.
    pub fn for_plan(
        part: &crate::partition::Partitioning,
        cm: &crate::partition::ClassMap,
        sigma2: Vec<f64>,
        gamma: Vec<f64>,
        workers: usize,
        latency: LatencyModel,
        omega: f64,
    ) -> TheoremLoss {
        assert_eq!(sigma2.len(), cm.n_classes, "one σ² per class");
        assert_eq!(gamma.len(), cm.n_classes, "one Γ per window");
        TheoremLoss {
            u: part.u,
            h: part.h,
            q: part.q,
            k: cm.class_sizes(),
            sigma2,
            gamma,
            workers,
            latency,
            omega,
            cxr_bound_factor: match part.paradigm {
                crate::partition::Paradigm::RowTimesCol => 1,
                crate::partition::Paradigm::ColTimesRow => part.m,
            },
        }
    }

    /// The same configuration under a different window polynomial (the
    /// shape `optimize_gamma` iterates over).
    pub fn with_gamma(&self, gamma: Vec<f64>) -> TheoremLoss {
        assert_eq!(gamma.len(), self.gamma.len(), "window count is fixed");
        TheoremLoss { gamma, ..self.clone() }
    }

    /// Eq. (19): probability that exactly `w` of `W` workers respond by
    /// time `t`.
    pub fn arrival_pmf(&self, w: usize, t: f64) -> f64 {
        binomial_pmf(self.workers, w, self.latency.cdf_scaled(t, self.omega))
    }

    /// `E[‖C‖²_F]` under Assumption 1 — the normalization constant
    /// (`UHQ·Σ_l k_l σ²_l`; cross terms vanish for zero-mean blocks).
    pub fn energy(&self) -> f64 {
        let uhq = (self.u * self.h * self.q) as f64;
        uhq * self
            .k
            .iter()
            .zip(self.sigma2.iter())
            .map(|(&k, &s)| k as f64 * s)
            .sum::<f64>()
    }

    /// Conditional expected loss given `w` received packets — eq. (23)
    /// (×`M` for the Theorem 3 bound).
    pub fn loss_given_packets(&self, strategy: UepStrategy, w: usize) -> f64 {
        let uhq = (self.u * self.h * self.q) as f64;
        let sum: f64 = self
            .k
            .iter()
            .zip(self.sigma2.iter())
            .enumerate()
            .map(|(l, (&k_l, &s2))| {
                let p_d = match strategy {
                    UepStrategy::Now => now_decode_prob(w, &self.gamma, &self.k, l),
                    UepStrategy::Ew => ew_decode_prob(w, &self.gamma, &self.k, l),
                };
                k_l as f64 * (1.0 - p_d) * s2
            })
            .sum();
        self.cxr_bound_factor as f64 * uhq * sum
    }

    /// The conditional-loss table over packet counts `w = 0..=W` —
    /// compute once per strategy, reuse across every deadline (the
    /// decoding probabilities don't depend on `t`).
    pub fn loss_table(&self, strategy: UepStrategy) -> Vec<f64> {
        (0..=self.workers)
            .map(|w| self.loss_given_packets(strategy, w))
            .collect()
    }

    /// Theorem 2/3: expected loss at deadline `t` — eq. (22)/(24).
    pub fn expected_loss(&self, strategy: UepStrategy, t: f64) -> f64 {
        self.expected_loss_with_table(&self.loss_table(strategy), t)
    }

    /// Expected loss at `t` from a precomputed [`Self::loss_table`].
    pub fn expected_loss_with_table(&self, table: &[f64], t: f64) -> f64 {
        table
            .iter()
            .enumerate()
            .map(|(w, &l)| self.arrival_pmf(w, t) * l)
            .sum()
    }

    /// Normalized expected loss at deadline `t` (the paper's Fig. 9
    /// y-axis): `E[L(t)] / E[‖C‖²]`.
    pub fn normalized_loss(&self, strategy: UepStrategy, t: f64) -> f64 {
        self.expected_loss(strategy, t) / self.energy()
    }

    /// Normalized expected-loss curve over many deadlines (computes the
    /// decoding-probability table once — ~40× faster than calling
    /// [`Self::normalized_loss`] per point).
    pub fn normalized_loss_curve(&self, strategy: UepStrategy, ts: &[f64]) -> Vec<f64> {
        let table = self.loss_table(strategy);
        let energy = self.energy();
        ts.iter()
            .map(|&t| self.expected_loss_with_table(&table, t) / energy)
            .collect()
    }

    /// Normalized conditional loss vs received packets (Fig. 10 y-axis).
    pub fn normalized_loss_vs_packets(&self, strategy: UepStrategy, w: usize) -> f64 {
        self.loss_given_packets(strategy, w) / self.energy()
    }
}

/// MDS normalized loss vs received packets: all-or-nothing at the
/// recovery threshold `K = Σ_l k_l`.
pub fn mds_loss_vs_packets(total_blocks: usize, received: usize) -> f64 {
    if received >= total_blocks {
        0.0
    } else {
        1.0
    }
}

/// MDS normalized expected loss vs time:
/// `P[N(t) < K] = Σ_{w<K} C(W,w) F^w (1−F)^{W−w}`.
pub fn mds_loss_vs_time(
    total_blocks: usize,
    workers: usize,
    latency: &LatencyModel,
    omega: f64,
    t: f64,
) -> f64 {
    let f = latency.cdf_scaled(t, omega);
    (0..total_blocks.min(workers + 1))
        .map(|w| binomial_pmf(workers, w, f))
        .sum()
}

/// δ-replication normalized expected loss vs time: a sub-product is
/// missing iff all `δ` replicas straggle, so `E[loss]/E[‖C‖²] =
/// (1−F(Ωt))^δ` (uncoded is `δ = 1`).
pub fn repetition_loss_vs_time(
    replicas: usize,
    latency: &LatencyModel,
    omega: f64,
    t: f64,
) -> f64 {
    (1.0 - latency.cdf_scaled(t, omega)).powi(replicas as i32)
}

/// δ-replication normalized loss vs received packets (uniformly random
/// arrival order): `P[block missing | w arrived] = C(W−δ, w)/C(W, w)`.
pub fn repetition_loss_vs_packets(workers: usize, replicas: usize, received: usize) -> f64 {
    assert!(replicas >= 1 && replicas <= workers);
    if received + replicas > workers {
        return 0.0;
    }
    (ln_binomial(workers - replicas, received) - ln_binomial(workers, received)).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Fig. 9 r×c configuration.
    fn fig9_rxc() -> TheoremLoss {
        TheoremLoss {
            u: 300,
            h: 900,
            q: 300,
            k: vec![3, 3, 3],
            // classes: {hh, hm} → 10·10 and 10·1 … the paper's class
            // variances (σ²_A·σ²_B per class, representative values):
            sigma2: vec![100.0, 10.0, 0.1],
            gamma: vec![0.40, 0.35, 0.25],
            workers: 30,
            latency: LatencyModel::exp(1.0),
            omega: 1.0,
            cxr_bound_factor: 1,
        }
    }

    #[test]
    fn loss_is_monotone_decreasing_in_time() {
        let th = fig9_rxc();
        for strat in [UepStrategy::Now, UepStrategy::Ew] {
            let mut prev = f64::INFINITY;
            for i in 0..20 {
                let t = i as f64 * 0.1;
                let l = th.normalized_loss(strat, t);
                assert!(l <= prev + 1e-9, "not monotone at t={t}");
                assert!((0.0..=1.0 + 1e-9).contains(&l));
                prev = l;
            }
        }
    }

    #[test]
    fn loss_at_zero_is_full_energy() {
        let th = fig9_rxc();
        assert!((th.normalized_loss(UepStrategy::Now, 0.0) - 1.0).abs() < 1e-9);
        assert!((th.normalized_loss(UepStrategy::Ew, 0.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn loss_vanishes_for_large_t() {
        let th = fig9_rxc();
        assert!(th.normalized_loss(UepStrategy::Now, 30.0) < 1e-3);
        assert!(th.normalized_loss(UepStrategy::Ew, 30.0) < 1e-3);
    }

    #[test]
    fn ew_beats_now_early_on_weighted_loss() {
        // EW protects the heavy class harder; early in time the weighted
        // loss should be lower than NOW's for the paper's setup.
        let th = fig9_rxc();
        let t = 0.3;
        let ew = th.normalized_loss(UepStrategy::Ew, t);
        let now = th.normalized_loss(UepStrategy::Now, t);
        assert!(ew < now, "t={t}: EW {ew} ≥ NOW {now}");
    }

    #[test]
    fn uep_beats_mds_early_and_loses_late() {
        // The paper's headline crossover (§VI, Fig. 9).
        let th = fig9_rxc();
        let mds = |t: f64| mds_loss_vs_time(9, 30, &th.latency, th.omega, t);
        let t_early = 0.2;
        assert!(th.normalized_loss(UepStrategy::Now, t_early) < mds(t_early));
        assert!(th.normalized_loss(UepStrategy::Ew, t_early) < mds(t_early));
        let t_late = 2.0;
        assert!(th.normalized_loss(UepStrategy::Ew, t_late) > mds(t_late));
    }

    #[test]
    fn mds_step_behavior_vs_packets() {
        assert_eq!(mds_loss_vs_packets(9, 8), 1.0);
        assert_eq!(mds_loss_vs_packets(9, 9), 0.0);
        assert_eq!(mds_loss_vs_packets(9, 30), 0.0);
    }

    #[test]
    fn repetition_curves() {
        let lat = LatencyModel::exp(1.0);
        // δ=2 strictly better than δ=1 at equal F (per-block missing prob)
        let t = 0.5;
        let r1 = repetition_loss_vs_time(1, &lat, 1.0, t);
        let r2 = repetition_loss_vs_time(2, &lat, 1.0, t);
        assert!(r2 < r1);
        // packets version: 0 received ⇒ loss 1; all received ⇒ 0
        assert!((repetition_loss_vs_packets(18, 2, 0) - 1.0).abs() < 1e-12);
        assert_eq!(repetition_loss_vs_packets(18, 2, 17), 0.0);
        // one replica of two still out with w=9 of 18: C(16,9)/C(18,9)
        let p = repetition_loss_vs_packets(18, 2, 9);
        assert!((p - (9.0 * 8.0) / (18.0 * 17.0) * 2.0).abs() > -1.0); // sanity: finite
        assert!(p > 0.0 && p < 1.0);
    }

    #[test]
    fn theorem3_bound_factor_scales() {
        let mut th = fig9_rxc();
        let base = th.expected_loss(UepStrategy::Now, 0.5);
        th.cxr_bound_factor = 9;
        let bound = th.expected_loss(UepStrategy::Now, 0.5);
        assert!((bound / base - 9.0).abs() < 1e-9);
    }

    #[test]
    fn loss_vs_packets_monotone() {
        let th = fig9_rxc();
        for strat in [UepStrategy::Now, UepStrategy::Ew] {
            let mut prev = f64::INFINITY;
            for w in 0..=30 {
                let l = th.normalized_loss_vs_packets(strat, w);
                assert!(l <= prev + 1e-9);
                prev = l;
            }
        }
    }
}
