//! Exact small-scale combinatorics: binomial/multinomial PMFs in log
//! space and composition enumeration for the multinomial sums in
//! eq. (20)/(21).

/// `ln Γ(n+1) = ln(n!)` via direct summation (exact enough for n ≤ 10⁴).
fn ln_factorial(n: usize) -> f64 {
    (2..=n).map(|i| (i as f64).ln()).sum()
}

/// `ln C(n, k)`.
pub fn ln_binomial(n: usize, k: usize) -> f64 {
    assert!(k <= n);
    ln_factorial(n) - ln_factorial(k) - ln_factorial(n - k)
}

/// Binomial PMF `P[X = k]`, `X ~ Binomial(n, p)` — eq. (19) with
/// `p = F(t)`.
pub fn binomial_pmf(n: usize, k: usize, p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p));
    if k > n {
        return 0.0;
    }
    if p == 0.0 {
        return if k == 0 { 1.0 } else { 0.0 };
    }
    if p == 1.0 {
        return if k == n { 1.0 } else { 0.0 };
    }
    (ln_binomial(n, k) + k as f64 * p.ln() + (n - k) as f64 * (1.0 - p).ln()).exp()
}

/// Multinomial PMF (eq. 21): probability of the window-count vector `n`
/// among `N = Σ n_l` packets with window probabilities `gamma`.
pub fn multinomial_pmf(counts: &[usize], gamma: &[f64]) -> f64 {
    assert_eq!(counts.len(), gamma.len());
    let n: usize = counts.iter().sum();
    let mut ln_p = ln_factorial(n);
    for (&c, &g) in counts.iter().zip(gamma.iter()) {
        if c > 0 && g == 0.0 {
            return 0.0;
        }
        ln_p -= ln_factorial(c);
        if c > 0 {
            ln_p += c as f64 * g.ln();
        }
    }
    ln_p.exp()
}

/// All compositions of `total` into `parts` non-negative integers
/// (lexicographic). `C(total+parts-1, parts-1)` vectors.
pub fn compositions(total: usize, parts: usize) -> Vec<Vec<usize>> {
    assert!(parts >= 1);
    let mut out = Vec::new();
    let mut cur = vec![0usize; parts];
    fn rec(cur: &mut Vec<usize>, idx: usize, remaining: usize, out: &mut Vec<Vec<usize>>) {
        if idx == cur.len() - 1 {
            cur[idx] = remaining;
            out.push(cur.clone());
            return;
        }
        for v in 0..=remaining {
            cur[idx] = v;
            rec(cur, idx + 1, remaining - v, out);
        }
    }
    rec(&mut cur, 0, total, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binomial_pmf_sums_to_one() {
        for &(n, p) in &[(10, 0.3), (30, 0.9), (1, 0.5), (30, 0.0), (5, 1.0)] {
            let total: f64 = (0..=n).map(|k| binomial_pmf(n, k, p)).sum();
            assert!((total - 1.0).abs() < 1e-12, "n={n} p={p}: {total}");
        }
    }

    #[test]
    fn binomial_known_value() {
        // C(4,2)·0.5⁴ = 6/16
        assert!((binomial_pmf(4, 2, 0.5) - 0.375).abs() < 1e-12);
    }

    #[test]
    fn multinomial_sums_to_one() {
        let gamma = [0.4, 0.35, 0.25];
        let n = 8;
        let total: f64 = compositions(n, 3)
            .iter()
            .map(|c| multinomial_pmf(c, &gamma))
            .sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn multinomial_marginal_is_binomial() {
        // marginal of n_0 over the multinomial = Binomial(N, γ_0)
        let gamma = [0.4, 0.35, 0.25];
        let n = 10;
        for k in 0..=n {
            let marg: f64 = compositions(n, 3)
                .iter()
                .filter(|c| c[0] == k)
                .map(|c| multinomial_pmf(c, &gamma))
                .sum();
            assert!((marg - binomial_pmf(n, k, 0.4)).abs() < 1e-10);
        }
    }

    #[test]
    fn compositions_count() {
        // C(total+parts-1, parts-1)
        assert_eq!(compositions(5, 3).len(), 21);
        assert_eq!(compositions(0, 4).len(), 1);
        assert_eq!(compositions(7, 1).len(), 1);
        for c in compositions(6, 3) {
            assert_eq!(c.iter().sum::<usize>(), 6);
        }
    }

    #[test]
    fn zero_probability_windows() {
        assert_eq!(multinomial_pmf(&[1, 0], &[0.0, 1.0]), 0.0);
        assert_eq!(multinomial_pmf(&[0, 2], &[0.0, 1.0]), 1.0);
    }
}
