//! Window selection polynomial `Γ(ξ) = Σ_l Γ_l ξ^l` from [19]: the
//! distribution over importance windows used by both NOW and EW UEP
//! codes. `Γ_0` is the probability of the *most important* window.

use crate::rng::{sample_discrete, Pcg64};

/// A probability distribution over `L` windows.
#[derive(Clone, Debug, PartialEq)]
pub struct WindowPolynomial {
    probs: Vec<f64>,
}

impl WindowPolynomial {
    /// Build from raw weights (normalized internally).
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty(), "empty window polynomial");
        assert!(weights.iter().all(|&w| w >= 0.0), "negative window weight");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all window weights zero");
        WindowPolynomial { probs: weights.iter().map(|w| w / total).collect() }
    }

    /// The paper's Table III polynomial: `(0.40, 0.35, 0.25)`.
    pub fn paper_table3() -> Self {
        WindowPolynomial::new(&[0.40, 0.35, 0.25])
    }

    /// Uniform over `l` windows (equal error protection).
    pub fn uniform(l: usize) -> Self {
        WindowPolynomial::new(&vec![1.0; l])
    }

    pub fn num_windows(&self) -> usize {
        self.probs.len()
    }

    /// `Γ_l` for window `l` (0-based; 0 = most important).
    pub fn prob(&self, l: usize) -> f64 {
        self.probs[l]
    }

    pub fn probs(&self) -> &[f64] {
        &self.probs
    }

    /// Sample a window index.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        sample_discrete(rng, &self.probs)
    }

    /// Truncate/renormalize to `l` windows (used when a class map has
    /// fewer classes than the configured polynomial).
    pub fn resized(&self, l: usize) -> WindowPolynomial {
        assert!(l >= 1);
        if l == self.probs.len() {
            return self.clone();
        }
        if l < self.probs.len() {
            WindowPolynomial::new(&self.probs[..l])
        } else {
            // extend with the last weight
            let mut w = self.probs.clone();
            let last = *w.last().unwrap();
            w.resize(l, last);
            WindowPolynomial::new(&w)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalizes() {
        let w = WindowPolynomial::new(&[4.0, 3.5, 2.5]);
        assert!((w.prob(0) - 0.40).abs() < 1e-12);
        assert!((w.probs().iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn paper_polynomial() {
        let w = WindowPolynomial::paper_table3();
        assert_eq!(w.num_windows(), 3);
        assert!((w.prob(2) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn sampling_frequencies() {
        let mut rng = Pcg64::seed_from(1);
        let w = WindowPolynomial::paper_table3();
        let mut counts = [0usize; 3];
        let n = 120_000;
        for _ in 0..n {
            counts[w.sample(&mut rng)] += 1;
        }
        for (c, p) in counts.iter().zip(w.probs()) {
            assert!((*c as f64 / n as f64 - p).abs() < 0.01);
        }
    }

    #[test]
    fn resize_down_and_up() {
        let w = WindowPolynomial::paper_table3();
        let w2 = w.resized(2);
        assert_eq!(w2.num_windows(), 2);
        assert!((w2.prob(0) - 0.40 / 0.75).abs() < 1e-12);
        let w4 = w.resized(4);
        assert_eq!(w4.num_windows(), 4);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_weights() {
        WindowPolynomial::new(&[0.0, 0.0]);
    }
}
