//! The PS-side progressive decoder.
//!
//! Packets arrive one at a time (ordered by worker completion). Each is
//! one linear equation over the unknown sub-products; [`DecodeState`]
//! absorbs it into an incremental Gauss–Jordan elimination and reports
//! which *real* unknowns became uniquely determined. Payloads (when
//! present) ride through the same row operations, so the value of a
//! determined unknown is read off its singleton RREF row — per-pivot
//! back-substitution instead of a batch `RᵀX = E` least-squares solve.
//! Coefficient-only simulation sweeps never touch matrix payloads at
//! all, and the decoder performs no per-packet row clone: the equation
//! buffer passes into the eliminator and comes back (absorbed or
//! rejected) for reuse by the next packet.

use crate::linalg::{Absorption, Eliminator, Matrix};

use super::{Packet, UnknownSpace};

/// Progressive decoding state over an unknown space.
pub struct DecodeState {
    space: UnknownSpace,
    elim: Eliminator,
    /// Shape of packet payload matrices, set by the first packet that
    /// carries one (payloads are flattened into the eliminator).
    payload_shape: Option<(usize, usize)>,
    /// Count of all packets offered (including dependent ones).
    offered: usize,
    /// Maintained count of determined *real* unknowns.
    recovered_real: usize,
    /// Spare coefficient buffer recycled across packets.
    spare_row: Vec<f64>,
}

impl DecodeState {
    pub fn new(space: UnknownSpace) -> Self {
        let n = space.n_total;
        DecodeState {
            space,
            elim: Eliminator::new(n, 0),
            payload_shape: None,
            offered: 0,
            recovered_real: 0,
            spare_row: Vec::new(),
        }
    }

    /// Reset to an empty decode over the same unknown space, keeping all
    /// backing allocations (scratch reuse across Monte-Carlo trials).
    pub fn reset(&mut self) {
        self.elim.reset(self.space.n_total, 0);
        self.payload_shape = None;
        self.offered = 0;
        self.recovered_real = 0;
    }

    pub fn space(&self) -> &UnknownSpace {
        &self.space
    }

    /// Number of packets offered so far.
    pub fn offered(&self) -> usize {
        self.offered
    }

    /// Rank of the absorbed system.
    pub fn rank(&self) -> usize {
        self.elim.rank()
    }

    /// Absorb a packet (with its computed payload, or `None` in
    /// coefficient-only mode). Returns the newly determined *real*
    /// unknown indices.
    pub fn add_packet(&mut self, packet: &Packet, payload: Option<Matrix>) -> Vec<usize> {
        let mut row = std::mem::take(&mut self.spare_row);
        packet.coeff_row_into(&self.space, &mut row);
        self.add_equation(row, payload)
    }

    /// Absorb a raw equation row (ownership passes to the eliminator; on
    /// a rank-deficient row the buffer is reclaimed for the next packet).
    pub fn add_equation(&mut self, row: Vec<f64>, payload: Option<Matrix>) -> Vec<usize> {
        self.offered += 1;
        let rhs = match payload {
            Some(m) => {
                let shape = m.shape();
                match self.payload_shape {
                    None => {
                        assert_eq!(
                            self.elim.rank(),
                            0,
                            "packets must carry payloads from the first arrival on"
                        );
                        self.payload_shape = Some(shape);
                        self.elim.set_payload_len(shape.0 * shape.1);
                    }
                    Some(s) => assert_eq!(s, shape, "payload shape changed mid-decode"),
                }
                m.into_vec()
            }
            None => {
                assert!(
                    self.payload_shape.is_none(),
                    "coefficient-only packet after payload-carrying packets"
                );
                Vec::new()
            }
        };
        match self.elim.insert(row, rhs) {
            Absorption::Absorbed { newly, coeff, rhs: _ } => {
                self.spare_row = coeff;
                let real: Vec<usize> =
                    newly.into_iter().filter(|&u| self.space.is_real(u)).collect();
                self.recovered_real += real.len();
                real
            }
            Absorption::Rejected { coeff, rhs: _ } => {
                self.spare_row = coeff;
                Vec::new()
            }
        }
    }

    /// Which real unknowns are currently determined.
    pub fn recovered_mask(&self) -> Vec<bool> {
        (0..self.space.n_real).map(|u| self.elim.is_determined(u)).collect()
    }

    /// Number of determined real unknowns (maintained, O(1)).
    pub fn num_recovered(&self) -> usize {
        self.recovered_real
    }

    /// All real unknowns determined?
    pub fn is_complete(&self) -> bool {
        self.recovered_real == self.space.n_real
    }

    /// Recovered payload of every determined real unknown, read directly
    /// off the eliminator's reduced right-hand sides (the incremental
    /// back-substitution maintained on every arrival). Undetermined
    /// unknowns come back as `None`.
    pub fn recover_values(&self) -> Vec<Option<Matrix>> {
        let mut out: Vec<Option<Matrix>> = vec![None; self.space.n_real];
        if self.recovered_real == 0 {
            return out;
        }
        let (pr, pc) = self.payload_shape.expect("recover_values needs payloads");
        for (u, slot) in out.iter_mut().enumerate() {
            if let Some(v) = self.elim.value_of(u) {
                *slot = Some(Matrix::from_vec(pr, pc, v.to_vec()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodeKind, CodeSpec, EncodeStyle, WindowPolynomial};
    use crate::linalg::matmul;
    use crate::partition::{default_pair_classes, ClassMap, Partitioning};
    use crate::rng::Pcg64;
    use crate::util::prop::{gen, prop_check, PropConfig};

    /// Compute a packet's payload honestly: build W_A, W_B per the
    /// recipe and multiply (what a worker does).
    fn worker_payload(
        part: &Partitioning,
        a_blocks: &[Matrix],
        b_blocks: &[Matrix],
        packet: &crate::coding::Packet,
    ) -> Matrix {
        use crate::coding::JobRecipe;
        match &packet.recipe {
            JobRecipe::Stacked { terms } => {
                let scaled_a: Vec<Matrix> = terms
                    .iter()
                    .map(|t| {
                        let (ai, _) = part.factors_of(t.unknown);
                        let mut m = a_blocks[ai].clone();
                        m.scale(t.coeff);
                        m
                    })
                    .collect();
                let parts_b: Vec<&Matrix> = terms
                    .iter()
                    .map(|t| {
                        let (_, bi) = part.factors_of(t.unknown);
                        &b_blocks[bi]
                    })
                    .collect();
                let wa = Matrix::hconcat(&scaled_a.iter().collect::<Vec<_>>());
                let wb = Matrix::vconcat(&parts_b);
                matmul(&wa, &wb)
            }
            JobRecipe::RankOne { a_coeffs, b_coeffs } => {
                let (u, h) = a_blocks[0].shape();
                let (_, q) = b_blocks[0].shape();
                let mut wa = Matrix::zeros(u, h);
                for &(i, alpha) in a_coeffs {
                    wa.axpy(alpha, &a_blocks[i]);
                }
                let mut wb = Matrix::zeros(h, q);
                for &(j, beta) in b_coeffs {
                    wb.axpy(beta, &b_blocks[j]);
                }
                matmul(&wa, &wb)
            }
        }
    }

    fn setups() -> Vec<(Partitioning, ClassMap)> {
        let pair = default_pair_classes(3);
        let rxc = Partitioning::rxc(3, 3, 4, 5, 4);
        let cm_rxc =
            ClassMap::from_levels(&rxc, vec![0, 1, 2], vec![0, 1, 2], &pair);
        let cxr = Partitioning::cxr(9, 6, 3, 5);
        let lv = vec![0, 0, 0, 1, 1, 1, 2, 2, 2];
        let cm_cxr = ClassMap::from_levels(&cxr, lv.clone(), lv, &pair);
        vec![(rxc, cm_rxc), (cxr, cm_cxr)]
    }

    fn all_specs(style_rank1_cxr: bool) -> Vec<CodeSpec> {
        let g = WindowPolynomial::paper_table3();
        let mut v = vec![
            CodeSpec::stacked(CodeKind::Uncoded),
            CodeSpec::stacked(CodeKind::Repetition),
            CodeSpec::stacked(CodeKind::Mds),
            CodeSpec::stacked(CodeKind::NowUep(g.clone())),
            CodeSpec::stacked(CodeKind::EwUep(g.clone())),
            CodeSpec::new(CodeKind::NowUep(g.clone()), EncodeStyle::RankOne),
            CodeSpec::new(CodeKind::EwUep(g.clone()), EncodeStyle::RankOne),
        ];
        if style_rank1_cxr {
            v.push(CodeSpec::new(CodeKind::Mds, EncodeStyle::RankOne));
        }
        v
    }

    /// The master correctness property: for every scheme × paradigm, if
    /// we feed ALL W packets, whatever the decoder marks as determined
    /// must decode to exactly the true sub-product; and with enough
    /// workers everything must decode (except rank-one c×r, which may
    /// legitimately not complete — ghosts absorb rank).
    #[test]
    fn decode_is_exact_for_all_schemes() {
        for (part, cm) in setups() {
            let mut rng = Pcg64::seed_from(99);
            let a = Matrix::randn(part.a_shape().0, part.a_shape().1, 0.0, 1.0, &mut rng);
            let b = Matrix::randn(part.b_shape().0, part.b_shape().1, 0.0, 1.0, &mut rng);
            let a_blocks = part.split_a(&a);
            let b_blocks = part.split_b(&b);
            let truth = part.true_products(&a, &b);
            for spec in all_specs(true) {
                let workers = 60; // plenty
                let pkts = spec.generate_packets(&part, &cm, workers, &mut rng);
                let space =
                    crate::coding::UnknownSpace::for_code(&part, spec.style);
                let mut st = DecodeState::new(space);
                for p in &pkts {
                    let payload = worker_payload(&part, &a_blocks, &b_blocks, p);
                    st.add_packet(p, Some(payload));
                }
                let values = st.recover_values();
                let mask = st.recovered_mask();
                for (u, (rec, val)) in mask.iter().zip(values.iter()).enumerate() {
                    if *rec {
                        let got = val.as_ref().expect("determined but no value");
                        assert!(
                            got.allclose(&truth[u], 1e-6),
                            "{} on {}: unknown {u} wrong",
                            spec.label(),
                            part.paradigm.short()
                        );
                    }
                }
                // with 60 workers every stacked scheme must fully decode
                if spec.style == EncodeStyle::Stacked {
                    assert!(
                        st.is_complete(),
                        "{} on {} incomplete with 60 workers",
                        spec.label(),
                        part.paradigm.short()
                    );
                }
            }
        }
    }

    #[test]
    fn mds_threshold_is_exactly_k() {
        let (part, cm) = &setups()[0];
        let mut rng = Pcg64::seed_from(5);
        let spec = CodeSpec::stacked(CodeKind::Mds);
        let pkts = spec.generate_packets(part, cm, 20, &mut rng);
        let space = crate::coding::UnknownSpace::for_code(part, spec.style);
        let mut st = DecodeState::new(space);
        for (i, p) in pkts.iter().enumerate() {
            st.add_packet(p, None);
            let k = part.num_products();
            if i + 1 < k {
                assert_eq!(st.num_recovered(), 0, "MDS decoded early at {}", i + 1);
            } else {
                assert!(st.is_complete(), "MDS not complete at {}", i + 1);
                break;
            }
        }
    }

    #[test]
    fn now_class_decodes_at_kl_packets() {
        // Feed only class-0 NOW packets: class decodes exactly at k_0.
        let (part, cm) = &setups()[0];
        let mut rng = Pcg64::seed_from(6);
        let spec = CodeSpec::stacked(CodeKind::NowUep(WindowPolynomial::paper_table3()));
        // generate many, filter window-0 packets
        let pkts: Vec<_> = spec
            .generate_packets(part, cm, 200, &mut rng)
            .into_iter()
            .filter(|p| p.window == 0)
            .collect();
        let k0 = cm.members[0].len();
        assert!(pkts.len() >= k0);
        let space = crate::coding::UnknownSpace::for_code(part, spec.style);
        let mut st = DecodeState::new(space);
        for (i, p) in pkts.iter().take(k0).enumerate() {
            let newly = st.add_packet(p, None);
            if i + 1 < k0 {
                assert!(newly.is_empty());
            } else {
                assert_eq!(newly.len(), k0);
            }
        }
        for &u in &cm.members[0] {
            assert!(st.recovered_mask()[u]);
        }
    }

    #[test]
    fn repetition_decodes_immediately() {
        let (part, cm) = &setups()[0];
        let mut rng = Pcg64::seed_from(7);
        let spec = CodeSpec::stacked(CodeKind::Repetition);
        let pkts = spec.generate_packets(part, cm, 18, &mut rng);
        let space = crate::coding::UnknownSpace::for_code(part, spec.style);
        let mut st = DecodeState::new(space);
        let newly = st.add_packet(&pkts[0], None);
        assert_eq!(newly.len(), 1);
        // the duplicate adds nothing
        let newly2 = st.add_packet(&pkts[9], None);
        assert!(newly2.is_empty());
        assert_eq!(st.rank(), 1);
    }

    /// Regression for the staircase-incompleteness bug: the empirical
    /// EW-UEP per-class decoding rate must match [19]'s analytic formula
    /// (a one-directional eliminator loses ~2× on class 0 because early
    /// wide packets hide solvable subsystems; the RREF decoder may not).
    #[test]
    fn ew_empirical_rate_matches_analysis() {
        let (part, cm) = setups().remove(1); // the paper's c×r setup
        let spec = CodeSpec::stacked(CodeKind::EwUep(WindowPolynomial::paper_table3()));
        let gamma = [0.40, 0.35, 0.25];
        let k = [3usize, 3, 3];
        let mut rng = Pcg64::seed_from(77);
        for n in [6usize, 9, 13] {
            let trials = 1500;
            let mut hits = [0usize; 3];
            for _ in 0..trials {
                let pkts = spec.generate_packets(&part, &cm, n, &mut rng);
                let space =
                    crate::coding::UnknownSpace::for_code(&part, spec.style);
                let mut st = DecodeState::new(space);
                for p in &pkts {
                    st.add_packet(p, None);
                }
                let mask = st.recovered_mask();
                for l in 0..3 {
                    if cm.members[l].iter().all(|&u| mask[u]) {
                        hits[l] += 1;
                    }
                }
            }
            for l in 0..3 {
                let emp = hits[l] as f64 / trials as f64;
                let ana = crate::analysis::ew_decode_prob(n, &gamma, &k, l);
                assert!(
                    (emp - ana).abs() < 0.04,
                    "N={n} class {l}: empirical {emp} vs analytic {ana}"
                );
            }
        }
    }

    /// Pre-refactor batch recovery oracle: solve `RᵀX = E_D` over the
    /// rank-increasing packet rows via least squares and combine the
    /// original payloads — the exact algorithm `recover_values` replaced
    /// with incremental per-pivot back-substitution.
    fn batch_recover(
        rows: &[Vec<f64>],
        payloads: &[Matrix],
        n_total: usize,
        determined: &[usize],
    ) -> Vec<Matrix> {
        let r = rows.len();
        let a = Matrix::from_fn(n_total, r, |i, w| rows[w][i]);
        let d = determined.len();
        let e = Matrix::from_fn(n_total, d, |i, c| {
            if i == determined[c] {
                1.0
            } else {
                0.0
            }
        });
        let x = crate::linalg::solve_least_squares(&a, &e)
            .expect("batch oracle: RRᵀ singular");
        let (pr, pc) = payloads[0].shape();
        determined
            .iter()
            .enumerate()
            .map(|(c, _)| {
                let mut acc = Matrix::zeros(pr, pc);
                for w in 0..r {
                    let coef = x[(w, c)];
                    if coef.abs() >= 1e-14 {
                        acc.axpy(coef, &payloads[w]);
                    }
                }
                acc
            })
            .collect()
    }

    /// Equivalence: the incremental value-recovery path must match the
    /// old batch least-squares solve (and the true sub-products) on
    /// randomized schemes, paradigms, and arrival orders — and the
    /// maintained `num_recovered` must match a mask recount after every
    /// single arrival.
    #[test]
    fn incremental_recovery_matches_batch_least_squares() {
        prop_check(
            "incremental vs batch recovery",
            PropConfig { cases: 12, seed: 2024 },
            |rng, case| {
                let setups = setups();
                let (part, cm) = &setups[case % setups.len()];
                let specs = all_specs(false);
                let spec = &specs[case % specs.len()];
                let a = Matrix::randn(part.a_shape().0, part.a_shape().1, 0.0, 1.0, rng);
                let b = Matrix::randn(part.b_shape().0, part.b_shape().1, 0.0, 1.0, rng);
                let a_blocks = part.split_a(&a);
                let b_blocks = part.split_b(&b);
                let truth = part.true_products(&a, &b);
                let workers = gen::usize_in(rng, 5, 45);
                let mut pkts = spec.generate_packets(part, cm, workers, rng);
                gen::shuffle(rng, &mut pkts);
                let space = crate::coding::UnknownSpace::for_code(part, spec.style);
                let n_total = space.n_total;
                let mut st = DecodeState::new(space);
                // the oracle's book-keeping: original rows + payloads of
                // rank-increasing packets
                let mut rows: Vec<Vec<f64>> = Vec::new();
                let mut payloads: Vec<Matrix> = Vec::new();
                for p in &pkts {
                    let payload = worker_payload(part, &a_blocks, &b_blocks, p);
                    let row = p.coeff_row(st.space());
                    let rank_before = st.rank();
                    st.add_packet(p, Some(payload.clone()));
                    if st.rank() > rank_before {
                        rows.push(row);
                        payloads.push(payload);
                    }
                    let recount =
                        st.recovered_mask().iter().filter(|&&m| m).count();
                    if recount != st.num_recovered() {
                        return Err(format!(
                            "maintained count {} vs recount {recount}",
                            st.num_recovered()
                        ));
                    }
                }
                let mask = st.recovered_mask();
                let determined: Vec<usize> = (0..mask.len())
                    .filter(|&u| mask[u])
                    .collect();
                let incremental = st.recover_values();
                if determined.is_empty() {
                    return Ok(());
                }
                let batch = batch_recover(&rows, &payloads, n_total, &determined);
                for (bi, &u) in determined.iter().enumerate() {
                    let inc = incremental[u]
                        .as_ref()
                        .ok_or("determined unknown missing incremental value")?;
                    if !inc.allclose(&batch[bi], 1e-6) {
                        return Err(format!("unknown {u}: incremental ≠ batch"));
                    }
                    if !inc.allclose(&truth[u], 1e-6) {
                        return Err(format!("unknown {u}: incremental ≠ truth"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn monotonic_recovery_property() {
        // recovery mask only ever grows, rank ≤ offered, and recovered
        // count never exceeds n_real — across random schemes and orders.
        prop_check("monotonic recovery", PropConfig { cases: 20, seed: 3 }, |rng, case| {
            let (part, cm) = &setups()[case % 2];
            let specs = all_specs(false);
            let spec = &specs[case % specs.len()];
            let w = gen::usize_in(rng, 1, 40);
            let pkts = spec.generate_packets(part, cm, w, rng);
            let space = crate::coding::UnknownSpace::for_code(part, spec.style);
            let mut st = DecodeState::new(space);
            let mut prev_mask = st.recovered_mask();
            for p in &pkts {
                st.add_packet(p, None);
                let mask = st.recovered_mask();
                for (a, b) in prev_mask.iter().zip(mask.iter()) {
                    if *a && !*b {
                        return Err("recovery regressed".to_string());
                    }
                }
                if st.rank() > st.offered() {
                    return Err("rank exceeds packet count".to_string());
                }
                prev_mask = mask;
            }
            Ok(())
        });
    }
}
