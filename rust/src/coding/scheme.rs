//! Packet generation for every coding scheme × encoding style.

use crate::partition::{ClassMap, Paradigm, Partitioning};
use crate::rng::{Normal, Pcg64};

use super::{RatelessCoder, RatelessSpec, WindowPolynomial};

/// The coding scheme (paper §IV + baselines from §VI–VII).
#[derive(Clone, Debug, PartialEq)]
pub enum CodeKind {
    /// One worker per sub-product, no protection.
    Uncoded,
    /// Each sub-product replicated across workers round-robin; the paper's
    /// "2-block repetition" uses `W = 2K`.
    Repetition,
    /// Dense random linear code over all sub-products; decodable exactly
    /// when `K` linearly independent packets arrive (real-Gaussian
    /// coefficients are MDS with probability 1).
    Mds,
    /// Non-Overlapping Window UEP: window `l` = class `l` only.
    NowUep(WindowPolynomial),
    /// Expanding Window UEP: window `l` = classes `0..=l`.
    EwUep(WindowPolynomial),
    /// Rateless LT/fountain UEP: no fixed `n` — workers stream packets
    /// derived per `(request, stream, seq)` until the decoder completes
    /// (see [`crate::coding::RatelessCoder`]). Under the fixed-rate
    /// [`CodeSpec::generate_packets`] entry point this degenerates to
    /// one seq-0 packet per worker.
    Rateless(RatelessSpec),
}

impl CodeKind {
    pub fn name(&self) -> &'static str {
        match self {
            CodeKind::Uncoded => "uncoded",
            CodeKind::Repetition => "repetition",
            CodeKind::Mds => "mds",
            CodeKind::NowUep(_) => "now-uep",
            CodeKind::EwUep(_) => "ew-uep",
            CodeKind::Rateless(_) => "rateless",
        }
    }
}

/// How packets are realized as two-factor worker jobs (DESIGN.md §2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EncodeStyle {
    /// Exact RLC via block stacking: `[c₁A_{n₁},…]·[B_{p₁};…]`.
    Stacked,
    /// The paper's literal eq. (17): `(Σαᵢ Aᵢ)(Σβⱼ Bⱼ)`.
    RankOne,
}

/// A fully specified code: scheme + encoding style.
#[derive(Clone, Debug, PartialEq)]
pub struct CodeSpec {
    pub kind: CodeKind,
    pub style: EncodeStyle,
}

impl CodeSpec {
    pub fn new(kind: CodeKind, style: EncodeStyle) -> Self {
        CodeSpec { kind, style }
    }

    pub fn stacked(kind: CodeKind) -> Self {
        CodeSpec { kind, style: EncodeStyle::Stacked }
    }

    pub fn label(&self) -> String {
        let style = match self.style {
            EncodeStyle::Stacked => "stacked",
            EncodeStyle::RankOne => "rank1",
        };
        format!("{}/{}", self.kind.name(), style)
    }
}

/// CLI token form: `uncoded`, `rep`, `mds`, `now`, `ew` (each with an
/// optional `-rank1` suffix) and `rateless[:delta=0.05,c=0.1]`. Window
/// codes print without their polynomial — the token form always means
/// the paper's Table III Γ, which is also what [`CodeSpec::from_str`]
/// reconstructs (callers with a custom Γ substitute it after parsing).
impl std::fmt::Display for CodeSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let head = match &self.kind {
            CodeKind::Uncoded => "uncoded",
            CodeKind::Repetition => "rep",
            CodeKind::Mds => "mds",
            CodeKind::NowUep(_) => "now",
            CodeKind::EwUep(_) => "ew",
            CodeKind::Rateless(_) => "rateless",
        };
        f.write_str(head)?;
        if self.style == EncodeStyle::RankOne {
            f.write_str("-rank1")?;
        }
        if let CodeKind::Rateless(sp) = &self.kind {
            write!(f, ":delta={},c={}", sp.delta, sp.c)?;
        }
        Ok(())
    }
}

impl std::str::FromStr for CodeSpec {
    type Err = String;

    /// Parse the token form accepted by `--code` (see [`CodeSpec`]'s
    /// `Display`). Examples: `ew`, `now-rank1`, `rateless`,
    /// `rateless:delta=0.05,c=0.1`.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let s = s.trim();
        let (head, params) = match s.split_once(':') {
            Some((h, p)) => (h, Some(p)),
            None => (s, None),
        };
        let (base, style) = match head.strip_suffix("-rank1") {
            Some(b) => (b, EncodeStyle::RankOne),
            None => (head, EncodeStyle::Stacked),
        };
        if params.is_some() && base != "rateless" {
            return Err(format!("code `{base}` takes no parameters"));
        }
        let gamma = WindowPolynomial::paper_table3;
        let kind = match base {
            "uncoded" => CodeKind::Uncoded,
            "rep" | "repetition" => CodeKind::Repetition,
            "mds" => CodeKind::Mds,
            "now" | "now-uep" => CodeKind::NowUep(gamma()),
            "ew" | "ew-uep" => CodeKind::EwUep(gamma()),
            "rateless" => {
                if style == EncodeStyle::RankOne {
                    return Err("rateless has no rank-1 form".to_string());
                }
                let mut spec = RatelessSpec::paper_default();
                for kv in params.unwrap_or("").split(',').filter(|p| !p.trim().is_empty()) {
                    let (key, val) = kv
                        .split_once('=')
                        .ok_or_else(|| format!("bad rateless parameter `{kv}` (want key=value)"))?;
                    let val: f64 = val
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad rateless value in `{kv}`"))?;
                    match key.trim() {
                        "delta" => spec.delta = val,
                        "c" => spec.c = val,
                        other => {
                            return Err(format!(
                                "unknown rateless parameter `{other}` (know delta, c)"
                            ))
                        }
                    }
                }
                if !(spec.delta > 0.0 && spec.delta < 1.0) {
                    return Err(format!("rateless delta {} outside (0,1)", spec.delta));
                }
                if spec.c <= 0.0 {
                    return Err(format!("rateless c {} must be positive", spec.c));
                }
                CodeKind::Rateless(spec)
            }
            other => {
                return Err(format!(
                    "unknown code `{other}` (know uncoded, rep, mds, now, ew, \
                     rateless[:delta=..,c=..]; `-rank1` suffix for the rank-one style)"
                ))
            }
        };
        Ok(CodeSpec { kind, style })
    }
}

/// One term of a stacked job: scale `coeff · A_{a}`, paired with `B_{b}`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StackTerm {
    /// Sub-product (unknown) index.
    pub unknown: usize,
    /// RLC coefficient.
    pub coeff: f64,
}

/// The worker-side recipe for constructing `W_A` and `W_B`.
#[derive(Clone, Debug, PartialEq)]
pub enum JobRecipe {
    /// `W_A = [c₁·A_{n₁}, …]`, `W_B = [B_{p₁}; …]` over the listed terms.
    Stacked { terms: Vec<StackTerm> },
    /// `W_A = Σ αᵢ·A_i`, `W_B = Σ βⱼ·B_j` (sparse coefficient lists over
    /// factor-block indices).
    RankOne {
        a_coeffs: Vec<(usize, f64)>,
        b_coeffs: Vec<(usize, f64)>,
    },
}

impl JobRecipe {
    /// Inner-dimension multiplier of this job relative to one plain
    /// sub-product (`k` for a k-term stacked job, 1 for rank-one).
    pub fn work_factor(&self) -> usize {
        match self {
            JobRecipe::Stacked { terms } => terms.len().max(1),
            JobRecipe::RankOne { .. } => 1,
        }
    }
}

/// One coded packet: the job assigned to one worker.
#[derive(Clone, Debug, PartialEq)]
pub struct Packet {
    pub worker: usize,
    /// The window/class this packet was generated for (diagnostics and
    /// the analysis comparisons). Baselines use 0.
    pub window: usize,
    pub recipe: JobRecipe,
}

impl Packet {
    /// Dense coefficient row of this packet over the (possibly extended)
    /// unknown space — the equation the decoder absorbs.
    pub fn coeff_row(&self, space: &UnknownSpace) -> Vec<f64> {
        let mut row = Vec::new();
        self.coeff_row_into(space, &mut row);
        row
    }

    /// Fill a caller-owned buffer with the coefficient row, reusing its
    /// allocation (the decoder's per-packet hot path).
    pub fn coeff_row_into(&self, space: &UnknownSpace, row: &mut Vec<f64>) {
        row.clear();
        row.resize(space.n_total, 0.0);
        match &self.recipe {
            JobRecipe::Stacked { terms } => {
                for t in terms {
                    row[t.unknown] += t.coeff;
                }
            }
            JobRecipe::RankOne { a_coeffs, b_coeffs } => {
                for &(i, alpha) in a_coeffs {
                    for &(j, beta) in b_coeffs {
                        let idx = space.index_of_pair(i, j);
                        row[idx] += alpha * beta;
                    }
                }
            }
        }
    }
}

/// The unknown space the decoder works over. Real unknowns `0..n_real`
/// are the sub-products of `C`; rank-one encoding over c×r additionally
/// produces *ghost* unknowns (off-diagonal cross products `A_i B_j`,
/// `i≠j`) that the decoder must carry but `Ĉ` never uses.
#[derive(Clone, Debug, PartialEq)]
pub struct UnknownSpace {
    pub n_real: usize,
    pub n_total: usize,
    paradigm: Paradigm,
    /// M for c×r pair indexing.
    m: usize,
    /// P for r×c pair indexing.
    p: usize,
}

impl UnknownSpace {
    /// Space for a given partitioning + encoding style.
    pub fn for_code(part: &Partitioning, style: EncodeStyle) -> Self {
        let n_real = part.num_products();
        let n_total = match (part.paradigm, style) {
            // r×c: every cross product (n,p) IS a real sub-product.
            (Paradigm::RowTimesCol, _) => n_real,
            (Paradigm::ColTimesRow, EncodeStyle::Stacked) => n_real,
            // c×r rank-one: all M² pairs, M real + M(M-1) ghosts.
            (Paradigm::ColTimesRow, EncodeStyle::RankOne) => part.m * part.m,
        };
        UnknownSpace {
            n_real,
            n_total,
            paradigm: part.paradigm,
            m: part.m,
            p: part.p,
        }
    }

    /// Unknown index of the factor pair `(a_idx, b_idx)`.
    pub fn index_of_pair(&self, a_idx: usize, b_idx: usize) -> usize {
        match self.paradigm {
            Paradigm::RowTimesCol => a_idx * self.p + b_idx,
            Paradigm::ColTimesRow => {
                if a_idx == b_idx {
                    a_idx
                } else {
                    // ghosts packed after the M real unknowns
                    let col = if b_idx < a_idx { b_idx } else { b_idx - 1 };
                    self.m + a_idx * (self.m - 1) + col
                }
            }
        }
    }

    /// Is this index a real sub-product of `C`?
    pub fn is_real(&self, idx: usize) -> bool {
        idx < self.n_real
    }
}

impl CodeSpec {
    /// Generate the packet (job) set for `workers` workers.
    pub fn generate_packets(
        &self,
        part: &Partitioning,
        cm: &ClassMap,
        workers: usize,
        rng: &mut Pcg64,
    ) -> Vec<Packet> {
        let k = part.num_products();
        assert!(workers >= 1);
        match &self.kind {
            CodeKind::Uncoded | CodeKind::Repetition => (0..workers)
                .map(|w| Packet {
                    worker: w,
                    window: 0,
                    recipe: JobRecipe::Stacked {
                        terms: vec![StackTerm { unknown: w % k, coeff: 1.0 }],
                    },
                })
                .collect(),
            CodeKind::Mds => (0..workers)
                .map(|w| Packet {
                    worker: w,
                    window: 0,
                    recipe: self.dense_recipe(part, &(0..k).collect::<Vec<_>>(), rng),
                })
                .collect(),
            CodeKind::NowUep(gamma) => {
                let gamma = gamma.resized(cm.n_classes);
                (0..workers)
                    .map(|w| {
                        let l = gamma.sample(rng);
                        Packet {
                            worker: w,
                            window: l,
                            recipe: self.window_recipe(part, cm, l, false, rng),
                        }
                    })
                    .collect()
            }
            CodeKind::EwUep(gamma) => {
                let gamma = gamma.resized(cm.n_classes);
                (0..workers)
                    .map(|w| {
                        let l = gamma.sample(rng);
                        Packet {
                            worker: w,
                            window: l,
                            recipe: self.window_recipe(part, cm, l, true, rng),
                        }
                    })
                    .collect()
            }
            CodeKind::Rateless(spec) => {
                // fixed-rate entry point: one seq-0 packet per worker
                // under a fresh request base, so every fixed-n consumer
                // (Plan, EncodedA, the encode cache) stays valid. True
                // open-ended streams go through RatelessCoder directly.
                let coder = RatelessCoder::from_class_map(spec, cm);
                let base = rng.next_u64();
                (0..workers).map(|w| coder.packet(base, w as u64, 0)).collect()
            }
        }
    }

    /// Dense recipe over an explicit unknown set (MDS and window codes).
    fn dense_recipe(
        &self,
        part: &Partitioning,
        unknowns: &[usize],
        rng: &mut Pcg64,
    ) -> JobRecipe {
        match self.style {
            EncodeStyle::Stacked => JobRecipe::Stacked {
                terms: unknowns
                    .iter()
                    .map(|&u| StackTerm { unknown: u, coeff: Normal::standard(rng) })
                    .collect(),
            },
            EncodeStyle::RankOne => {
                // dense over the factor blocks touched by the unknown set
                let mut a_set: Vec<usize> = Vec::new();
                let mut b_set: Vec<usize> = Vec::new();
                for &u in unknowns {
                    let (ai, bi) = part.factors_of(u);
                    if !a_set.contains(&ai) {
                        a_set.push(ai);
                    }
                    if !b_set.contains(&bi) {
                        b_set.push(bi);
                    }
                }
                JobRecipe::RankOne {
                    a_coeffs: a_set
                        .into_iter()
                        .map(|i| (i, Normal::standard(rng)))
                        .collect(),
                    b_coeffs: b_set
                        .into_iter()
                        .map(|j| (j, Normal::standard(rng)))
                        .collect(),
                }
            }
        }
    }

    /// Recipe for window `l` (NOW: class `l` exactly; EW: classes `0..=l`).
    fn window_recipe(
        &self,
        part: &Partitioning,
        cm: &ClassMap,
        l: usize,
        expanding: bool,
        rng: &mut Pcg64,
    ) -> JobRecipe {
        match self.style {
            EncodeStyle::Stacked => {
                let unknowns: Vec<usize> = if expanding {
                    cm.window_leq(l)
                } else {
                    cm.members[l].clone()
                };
                self.dense_recipe(part, &unknowns, rng)
            }
            EncodeStyle::RankOne => {
                if expanding {
                    let unknowns = cm.window_leq(l);
                    self.dense_recipe(part, &unknowns, rng)
                } else {
                    // NOW rank-one: pick one (a-level, b-level) grid cell of
                    // class l, then combine the blocks of those levels.
                    let cells = now_cells(part, cm, l);
                    let (la, lb) = cells[rng.next_bounded(cells.len() as u64) as usize];
                    let a_blocks: Vec<usize> = (0..part.num_a_blocks())
                        .filter(|&i| cm.a_level[i] == la)
                        .collect();
                    let b_blocks: Vec<usize> = (0..part.num_b_blocks())
                        .filter(|&j| cm.b_level[j] == lb)
                        .collect();
                    JobRecipe::RankOne {
                        a_coeffs: a_blocks
                            .into_iter()
                            .map(|i| (i, Normal::standard(rng)))
                            .collect(),
                        b_coeffs: b_blocks
                            .into_iter()
                            .map(|j| (j, Normal::standard(rng)))
                            .collect(),
                    }
                }
            }
        }
    }
}

/// The (a-level, b-level) grid cells whose products fall in class `l` and
/// which are realizable (both level sets non-empty).
fn now_cells(part: &Partitioning, cm: &ClassMap, l: usize) -> Vec<(usize, usize)> {
    let mut cells = Vec::new();
    for &u in &cm.members[l] {
        let (ai, bi) = part.factors_of(u);
        let cell = (cm.a_level[ai], cm.b_level[bi]);
        if !cells.contains(&cell) {
            cells.push(cell);
        }
    }
    assert!(!cells.is_empty(), "class {l} has no realizable grid cells");
    cells
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::default_pair_classes;

    fn paper_rxc() -> (Partitioning, ClassMap) {
        let part = Partitioning::rxc(3, 3, 2, 2, 2);
        let pair = default_pair_classes(3);
        let cm =
            ClassMap::from_levels(&part, vec![0, 1, 2], vec![0, 1, 2], &pair);
        (part, cm)
    }

    fn paper_cxr() -> (Partitioning, ClassMap) {
        let part = Partitioning::cxr(9, 2, 2, 2);
        let lv = vec![0, 0, 0, 1, 1, 1, 2, 2, 2];
        let pair = default_pair_classes(3);
        let cm = ClassMap::from_levels(&part, lv.clone(), lv, &pair);
        (part, cm)
    }

    #[test]
    fn uncoded_covers_all_unknowns() {
        let (part, cm) = paper_rxc();
        let mut rng = Pcg64::seed_from(1);
        let spec = CodeSpec::stacked(CodeKind::Uncoded);
        let pkts = spec.generate_packets(&part, &cm, 9, &mut rng);
        let mut covered = vec![false; 9];
        for p in &pkts {
            if let JobRecipe::Stacked { terms } = &p.recipe {
                assert_eq!(terms.len(), 1);
                assert_eq!(terms[0].coeff, 1.0);
                covered[terms[0].unknown] = true;
            } else {
                panic!("uncoded must be stacked");
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn repetition_replicates_each_unknown() {
        let (part, cm) = paper_rxc();
        let mut rng = Pcg64::seed_from(2);
        let spec = CodeSpec::stacked(CodeKind::Repetition);
        let pkts = spec.generate_packets(&part, &cm, 18, &mut rng);
        let mut counts = vec![0usize; 9];
        for p in &pkts {
            if let JobRecipe::Stacked { terms } = &p.recipe {
                counts[terms[0].unknown] += 1;
            }
        }
        assert!(counts.iter().all(|&c| c == 2));
    }

    #[test]
    fn mds_stacked_is_dense() {
        let (part, cm) = paper_rxc();
        let mut rng = Pcg64::seed_from(3);
        let spec = CodeSpec::stacked(CodeKind::Mds);
        let pkts = spec.generate_packets(&part, &cm, 5, &mut rng);
        let space = UnknownSpace::for_code(&part, EncodeStyle::Stacked);
        for p in &pkts {
            let row = p.coeff_row(&space);
            assert!(row.iter().all(|&c| c != 0.0));
            assert_eq!(p.recipe.work_factor(), 9);
        }
    }

    #[test]
    fn now_stacked_supports_exactly_one_class() {
        let (part, cm) = paper_rxc();
        let mut rng = Pcg64::seed_from(4);
        let spec = CodeSpec::stacked(CodeKind::NowUep(WindowPolynomial::paper_table3()));
        let space = UnknownSpace::for_code(&part, EncodeStyle::Stacked);
        for p in spec.generate_packets(&part, &cm, 50, &mut rng) {
            let row = p.coeff_row(&space);
            for (u, &c) in row.iter().enumerate() {
                if c != 0.0 {
                    assert_eq!(cm.class_of[u], p.window, "unknown {u} leaked");
                }
            }
            // and the full class is covered
            for &u in &cm.members[p.window] {
                assert!(row[u] != 0.0);
            }
        }
    }

    #[test]
    fn ew_stacked_supports_prefix_classes() {
        let (part, cm) = paper_rxc();
        let mut rng = Pcg64::seed_from(5);
        let spec = CodeSpec::stacked(CodeKind::EwUep(WindowPolynomial::paper_table3()));
        let space = UnknownSpace::for_code(&part, EncodeStyle::Stacked);
        for p in spec.generate_packets(&part, &cm, 50, &mut rng) {
            let row = p.coeff_row(&space);
            for (u, &c) in row.iter().enumerate() {
                if c != 0.0 {
                    assert!(cm.class_of[u] <= p.window);
                }
            }
            // class 0 is always fully covered (the EW guarantee)
            for &u in &cm.members[0] {
                assert!(row[u] != 0.0, "EW packet missing class-0 unknown {u}");
            }
        }
    }

    #[test]
    fn now_rank1_rxc_is_one_grid_cell() {
        let (part, cm) = paper_rxc();
        let mut rng = Pcg64::seed_from(6);
        let spec = CodeSpec::new(
            CodeKind::NowUep(WindowPolynomial::paper_table3()),
            EncodeStyle::RankOne,
        );
        let space = UnknownSpace::for_code(&part, EncodeStyle::RankOne);
        for p in spec.generate_packets(&part, &cm, 60, &mut rng) {
            if let JobRecipe::RankOne { a_coeffs, b_coeffs } = &p.recipe {
                // all a blocks same level, all b blocks same level
                let la = cm.a_level[a_coeffs[0].0];
                assert!(a_coeffs.iter().all(|&(i, _)| cm.a_level[i] == la));
                let lb = cm.b_level[b_coeffs[0].0];
                assert!(b_coeffs.iter().all(|&(j, _)| cm.b_level[j] == lb));
                // every supported unknown is in the packet's class: grid
                // cells are class-pure for the r×c paradigm
                let row = p.coeff_row(&space);
                for (u, &c) in row.iter().enumerate() {
                    if c != 0.0 {
                        assert_eq!(cm.class_of[u], p.window);
                    }
                }
            } else {
                panic!("expected rank-one recipe");
            }
        }
    }

    #[test]
    fn cxr_rank1_ghost_indexing_bijective() {
        let (part, _) = paper_cxr();
        let space = UnknownSpace::for_code(&part, EncodeStyle::RankOne);
        assert_eq!(space.n_real, 9);
        assert_eq!(space.n_total, 81);
        let mut seen = vec![false; 81];
        for i in 0..9 {
            for j in 0..9 {
                let idx = space.index_of_pair(i, j);
                assert!(!seen[idx], "collision at ({i},{j})");
                seen[idx] = true;
                assert_eq!(space.is_real(idx), i == j);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn cxr_rank1_packets_have_ghost_support() {
        let (part, cm) = paper_cxr();
        let mut rng = Pcg64::seed_from(7);
        let spec = CodeSpec::new(
            CodeKind::NowUep(WindowPolynomial::paper_table3()),
            EncodeStyle::RankOne,
        );
        let space = UnknownSpace::for_code(&part, EncodeStyle::RankOne);
        let pkts = spec.generate_packets(&part, &cm, 30, &mut rng);
        // at least one multi-block packet must touch a ghost unknown
        let any_ghost = pkts.iter().any(|p| {
            p.coeff_row(&space)
                .iter()
                .enumerate()
                .any(|(u, &c)| c != 0.0 && !space.is_real(u))
        });
        assert!(any_ghost, "c×r rank-one should create cross terms");
    }

    #[test]
    fn window_resizing_handles_fewer_classes() {
        // 2-class map with a 3-window polynomial: must not panic.
        let part = Partitioning::cxr(4, 2, 2, 2);
        let lv = vec![0, 0, 2, 2];
        let pair = default_pair_classes(3);
        let cm = ClassMap::from_levels(&part, lv.clone(), lv, &pair);
        assert_eq!(cm.n_classes, 2);
        let mut rng = Pcg64::seed_from(8);
        let spec = CodeSpec::stacked(CodeKind::NowUep(WindowPolynomial::paper_table3()));
        let pkts = spec.generate_packets(&part, &cm, 20, &mut rng);
        assert!(pkts.iter().all(|p| p.window < 2));
    }

    #[test]
    fn rateless_fixed_rate_entry_point_generates_valid_stacked_packets() {
        let (part, cm) = paper_rxc();
        let mut rng = Pcg64::seed_from(9);
        let spec = CodeSpec::stacked(CodeKind::Rateless(
            crate::coding::RatelessSpec::paper_default(),
        ));
        let space = UnknownSpace::for_code(&part, EncodeStyle::Stacked);
        let pkts = spec.generate_packets(&part, &cm, 12, &mut rng);
        assert_eq!(pkts.len(), 12);
        for (w, p) in pkts.iter().enumerate() {
            assert_eq!(p.worker, w);
            assert!(matches!(p.recipe, JobRecipe::Stacked { .. }));
            // every supported unknown sits inside the packet's window
            for (u, &c) in p.coeff_row(&space).iter().enumerate() {
                if c != 0.0 {
                    assert!(cm.class_of[u] <= p.window);
                }
            }
        }
    }

    #[test]
    fn code_spec_tokens_round_trip_through_fromstr_and_display() {
        for token in
            ["uncoded", "rep", "mds", "now", "ew", "now-rank1", "ew-rank1",
             "rateless:delta=0.05,c=0.1"]
        {
            let spec: CodeSpec = token.parse().unwrap();
            assert_eq!(spec.to_string(), token, "token {token}");
            let again: CodeSpec = spec.to_string().parse().unwrap();
            assert_eq!(again, spec, "token {token}");
        }
        // bare `rateless` carries the documented defaults
        let spec: CodeSpec = "rateless".parse().unwrap();
        match &spec.kind {
            CodeKind::Rateless(sp) => {
                assert_eq!(sp.delta, 0.05);
                assert_eq!(sp.c, 0.1);
            }
            other => panic!("parsed {other:?}"),
        }
        // parameters override the defaults
        let spec: CodeSpec = "rateless:c=0.2".parse().unwrap();
        match &spec.kind {
            CodeKind::Rateless(sp) => assert_eq!(sp.c, 0.2),
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn code_spec_parser_rejects_malformed_input() {
        for bad in [
            "",
            "nope",
            "ew:delta=1",
            "rateless-rank1",
            "rateless:delta=2",
            "rateless:c=-1",
            "rateless:spikes=3",
            "rateless:delta",
        ] {
            assert!(bad.parse::<CodeSpec>().is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn work_factors() {
        let r = JobRecipe::Stacked {
            terms: vec![
                StackTerm { unknown: 0, coeff: 1.0 },
                StackTerm { unknown: 3, coeff: -0.5 },
            ],
        };
        assert_eq!(r.work_factor(), 2);
        let r1 = JobRecipe::RankOne { a_coeffs: vec![(0, 1.0)], b_coeffs: vec![(0, 1.0)] };
        assert_eq!(r1.work_factor(), 1);
    }
}
