//! Rateless (LT/fountain) UEP encoding — the `CodeKind::Rateless` family.
//!
//! Fixed-rate codes (MDS/NOW/EW) draw the whole packet set at plan time,
//! so a worker that finishes 3 of its 4 jobs before the deadline
//! contributes nothing. LT codes have no fixed `n`: every worker derives
//! an endless stream of coded packets and the coordinator decodes as
//! soon as the arrivals span the unknown space, so a straggler's partial
//! stream is real progress instead of a write-off.
//!
//! The UEP twist keeps the paper's unequal protection: each packet first
//! samples an *expanding window* `l` (classes `0..=l`) from the window
//! polynomial `Γ(ξ)`, then a degree from a robust-Soliton distribution
//! over that window's size, then that many distinct unknowns uniformly
//! inside the window. Class-0 unknowns belong to every window, so the
//! most important sub-products appear in the most packets.
//!
//! Determinism is the load-bearing trick: a packet is a pure function of
//! `(request_id, stream, seq)` — both ends run the same
//! [`RatelessCoder`] and derive identical coefficient rows, so the wire
//! carries only matrix payloads, never coefficients, and *any* worker
//! can regenerate *any* lost packet (the `Redo` path).

use crate::partition::ClassMap;
use crate::rng::{Normal, Pcg64};

use super::{JobRecipe, Packet, StackTerm, WindowPolynomial};

/// Stream-selector namespace for packet derivation: packet `seq` of a
/// rateless stream draws from `Pcg64::with_stream(mix(request, stream),
/// BASE ^ seq)`, keeping packet streams disjoint from every other RNG
/// consumer (delays, probes, chaos) by construction.
const RATELESS_STREAM_BASE: u64 = 0x5EED_17C0_4A7E_1E55;

/// Parameters of the rateless family: the robust-Soliton knobs
/// `(δ, c)` and the UEP window polynomial `Γ(ξ)` (resized to the class
/// map at coder construction, exactly like the fixed-rate UEP codes).
#[derive(Clone, Debug, PartialEq)]
pub struct RatelessSpec {
    /// Robust-Soliton failure parameter `δ ∈ (0, 1)`.
    pub delta: f64,
    /// Robust-Soliton spike constant `c > 0`.
    pub c: f64,
    /// Window-sampling weights (window 0 = most important classes).
    pub gamma: WindowPolynomial,
}

impl RatelessSpec {
    pub fn new(delta: f64, c: f64, gamma: WindowPolynomial) -> Self {
        assert!(delta > 0.0 && delta < 1.0, "delta must be in (0,1)");
        assert!(c > 0.0, "c must be positive");
        RatelessSpec { delta, c, gamma }
    }

    /// The defaults used by `--code rateless`: `δ = 0.05`, `c = 0.1`,
    /// the paper's Table III window polynomial.
    pub fn paper_default() -> Self {
        RatelessSpec::new(0.05, 0.1, WindowPolynomial::paper_table3())
    }
}

/// The expanding windows a coder samples from: `windows[l]` holds the
/// unknown indices of classes `0..=l`, in ascending index order.
///
/// Both constructors produce identical windows for the same
/// classification — [`UepWindows::from_class_map`] is the coordinator
/// path, [`UepWindows::from_class_of`] rebuilds them worker-side from
/// the per-unknown class vector shipped in the rateless job frame.
#[derive(Clone, Debug, PartialEq)]
pub struct UepWindows {
    windows: Vec<Vec<usize>>,
}

impl UepWindows {
    pub fn from_class_map(cm: &ClassMap) -> Self {
        let class_of: Vec<u32> = cm.class_of.iter().map(|&c| c as u32).collect();
        Self::from_class_of(&class_of)
    }

    /// Rebuild from a per-unknown class vector (wire form). Windows are
    /// filled in ascending unknown order so the worker derives exactly
    /// the coordinator's windows.
    pub fn from_class_of(class_of: &[u32]) -> Self {
        assert!(!class_of.is_empty(), "empty class vector");
        let n_classes = *class_of.iter().max().unwrap() as usize + 1;
        let windows = (0..n_classes)
            .map(|l| {
                (0..class_of.len())
                    .filter(|&u| (class_of[u] as usize) <= l)
                    .collect::<Vec<usize>>()
            })
            .collect::<Vec<_>>();
        assert!(!windows[0].is_empty(), "window 0 has no unknowns");
        UepWindows { windows }
    }

    pub fn num_windows(&self) -> usize {
        self.windows.len()
    }

    pub fn window(&self, l: usize) -> &[usize] {
        &self.windows[l]
    }

    /// Total unknowns (the size of the widest window).
    pub fn num_unknowns(&self) -> usize {
        self.windows.last().map_or(0, |w| w.len())
    }
}

/// Robust-Soliton probability mass over degrees `1..=k` (returned as a
/// `Vec` with `pmf[i]` = probability of degree `i+1`).
///
/// Ideal part `ρ(1) = 1/k`, `ρ(i) = 1/(i(i−1))`; spike part
/// `τ(i) = R/(ik)` for `i < ⌊k/R⌋`, `τ(⌊k/R⌋) = R·ln(R/δ)/k` with
/// `R = c·ln(k/δ)·√k` (clamped to `≥ 1` so tiny windows stay valid);
/// normalized sum. `k = 1` degenerates to certain degree 1.
pub fn robust_soliton(k: usize, delta: f64, c: f64) -> Vec<f64> {
    assert!(k >= 1);
    if k == 1 {
        return vec![1.0];
    }
    let kf = k as f64;
    let r = (c * (kf / delta).ln() * kf.sqrt()).max(1.0);
    let spike = ((kf / r).floor() as usize).clamp(1, k);
    let mut pmf = vec![0.0; k];
    pmf[0] = 1.0 / kf;
    for i in 2..=k {
        pmf[i - 1] = 1.0 / (i as f64 * (i as f64 - 1.0));
    }
    for (i, p) in pmf.iter_mut().enumerate().take(spike - 1) {
        *p += r / ((i + 1) as f64 * kf);
    }
    pmf[spike - 1] += (r * (r / delta).ln() / kf).max(0.0);
    let total: f64 = pmf.iter().sum();
    for p in &mut pmf {
        *p /= total;
    }
    pmf
}

/// Sample an index from a CDF (inverse-transform with binary search).
fn sample_cdf(cdf: &[f64], rng: &mut Pcg64) -> usize {
    let u = rng.next_f64();
    cdf.partition_point(|&x| x <= u).min(cdf.len() - 1)
}

/// Mix a request id and a stream selector into one seed (splitmix64
/// finalizer over their combination, so nearby ids land far apart).
fn mix(request_id: u64, stream: u64) -> u64 {
    let mut z = request_id
        ^ stream.rotate_left(32)
        ^ 0x9E37_79B9_7F4A_7C15;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A deterministic rateless packet generator. Construction precomputes
/// one robust-Soliton CDF per window; [`RatelessCoder::packet`] is then
/// a pure function of `(request_id, stream, seq)` — the property the
/// whole v5 protocol leans on (coefficients never cross the wire).
#[derive(Clone, Debug)]
pub struct RatelessCoder {
    gamma: WindowPolynomial,
    windows: UepWindows,
    /// `cdfs[l][d-1]` = P(degree ≤ d) inside window `l`.
    cdfs: Vec<Vec<f64>>,
}

impl RatelessCoder {
    pub fn new(delta: f64, c: f64, gamma: &WindowPolynomial, windows: UepWindows) -> Self {
        assert!(delta > 0.0 && delta < 1.0, "delta must be in (0,1)");
        assert!(c > 0.0, "c must be positive");
        let gamma = gamma.resized(windows.num_windows());
        let cdfs = (0..windows.num_windows())
            .map(|l| {
                let pmf = robust_soliton(windows.window(l).len(), delta, c);
                let mut acc = 0.0;
                pmf.iter()
                    .map(|p| {
                        acc += p;
                        acc
                    })
                    .collect::<Vec<f64>>()
            })
            .collect();
        RatelessCoder { gamma, windows, cdfs }
    }

    /// Build from a spec and a class map (coordinator side).
    pub fn from_class_map(spec: &RatelessSpec, cm: &ClassMap) -> Self {
        Self::new(spec.delta, spec.c, &spec.gamma, UepWindows::from_class_map(cm))
    }

    pub fn num_unknowns(&self) -> usize {
        self.windows.num_unknowns()
    }

    pub fn windows(&self) -> &UepWindows {
        &self.windows
    }

    /// Window-selection probabilities actually in use (post-resize).
    pub fn gamma(&self) -> &WindowPolynomial {
        &self.gamma
    }

    /// Derive packet `seq` of stream `stream` for `request_id`. Pure and
    /// stateless: every call with the same arguments yields the same
    /// packet on any host, thread count, or transport.
    pub fn packet(&self, request_id: u64, stream: u64, seq: u32) -> Packet {
        let mut rng = Pcg64::with_stream(
            mix(request_id, stream),
            RATELESS_STREAM_BASE ^ seq as u64,
        );
        let l = self.gamma.sample(&mut rng);
        let window = self.windows.window(l);
        let d = sample_cdf(&self.cdfs[l], &mut rng) + 1;
        // d distinct unknowns via partial Fisher–Yates on a scratch copy
        let mut pool = window.to_vec();
        let mut terms = Vec::with_capacity(d);
        for i in 0..d {
            let j = i + rng.next_bounded((pool.len() - i) as u64) as usize;
            pool.swap(i, j);
            terms.push(StackTerm { unknown: pool[i], coeff: Normal::standard(&mut rng) });
        }
        Packet {
            worker: stream as usize,
            window: l,
            recipe: JobRecipe::Stacked { terms },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{DecodeState, UnknownSpace};
    use crate::linalg::{matmul, Matrix};
    use crate::partition::{default_pair_classes, ClassMap, Partitioning};
    use crate::util::prop::{gen, prop_check, PropConfig};

    fn paper_setup() -> (Partitioning, ClassMap) {
        let part = Partitioning::rxc(3, 3, 2, 2, 2);
        let pair = default_pair_classes(3);
        let cm = ClassMap::from_levels(&part, vec![0, 1, 2], vec![0, 1, 2], &pair);
        (part, cm)
    }

    #[test]
    fn robust_soliton_is_a_distribution() {
        for k in [1usize, 2, 3, 9, 40, 200] {
            let pmf = robust_soliton(k, 0.05, 0.1);
            assert_eq!(pmf.len(), k);
            assert!(pmf.iter().all(|&p| p >= 0.0), "k={k}: negative mass");
            let total: f64 = pmf.iter().sum();
            assert!((total - 1.0).abs() < 1e-12, "k={k}: sums to {total}");
            // degree 1 must have positive mass (decoding can start)
            assert!(pmf[0] > 0.0, "k={k}: no degree-1 packets");
        }
    }

    #[test]
    fn robust_soliton_spike_dominates_its_ideal_neighbourhood() {
        // the τ spike at ⌊k/R⌋ must lift that degree above the bare
        // ideal-Soliton mass 1/(i(i−1))
        let k = 100usize;
        let delta = 0.05;
        let c = 0.1;
        let pmf = robust_soliton(k, delta, c);
        let kf = k as f64;
        let r = (c * (kf / delta).ln() * kf.sqrt()).max(1.0);
        let spike = ((kf / r).floor() as usize).clamp(1, k);
        assert!(spike > 2 && spike < k, "test wants an interior spike, got {spike}");
        // the spiked degree towers over both neighbours (τ ≫ ρ there)
        assert!(pmf[spike - 1] > 10.0 * pmf[spike - 2], "spike at {spike} not visible");
        assert!(pmf[spike - 1] > 10.0 * pmf[spike], "spike at {spike} not visible");
    }

    #[test]
    fn windows_expand_and_match_across_constructors() {
        let (_, cm) = paper_setup();
        let w1 = UepWindows::from_class_map(&cm);
        let wire: Vec<u32> = cm.class_of.iter().map(|&c| c as u32).collect();
        let w2 = UepWindows::from_class_of(&wire);
        assert_eq!(w1, w2, "coordinator and worker windows must agree");
        for l in 1..w1.num_windows() {
            let prev = w1.window(l - 1);
            assert!(w1.window(l).len() >= prev.len());
            for u in prev {
                assert!(w1.window(l).contains(u), "window {l} lost unknown {u}");
            }
        }
        assert_eq!(w1.num_unknowns(), cm.class_of.len());
    }

    #[test]
    fn packets_are_a_pure_function_of_request_stream_seq() {
        let (_, cm) = paper_setup();
        let spec = RatelessSpec::paper_default();
        let coder = RatelessCoder::from_class_map(&spec, &cm);
        let coder2 = RatelessCoder::from_class_map(&spec, &cm);
        for stream in 0..4u64 {
            for seq in 0..50u32 {
                let p1 = coder.packet(0xABCD, stream, seq);
                let p2 = coder2.packet(0xABCD, stream, seq);
                assert_eq!(p1, p2, "stream {stream} seq {seq} diverged");
            }
        }
        // different coordinates give different draws
        let a = coder.packet(1, 0, 0);
        let b = coder.packet(1, 0, 1);
        let c = coder.packet(1, 1, 0);
        let d = coder.packet(2, 0, 0);
        assert!(a != b && a != c && a != d, "packet streams collide");
    }

    #[test]
    fn packet_terms_are_distinct_and_inside_the_window() {
        let (_, cm) = paper_setup();
        let spec = RatelessSpec::paper_default();
        let coder = RatelessCoder::from_class_map(&spec, &cm);
        for seq in 0..400u32 {
            let p = coder.packet(7, 0, seq);
            let JobRecipe::Stacked { terms } = &p.recipe else {
                panic!("rateless packets must be stacked");
            };
            assert!(!terms.is_empty());
            let window = coder.windows().window(p.window);
            let mut seen = Vec::new();
            for t in terms {
                assert!(window.contains(&t.unknown), "unknown escaped window");
                assert!(!seen.contains(&t.unknown), "duplicate unknown in packet");
                assert!(t.coeff != 0.0);
                seen.push(t.unknown);
            }
        }
    }

    /// An endless stream from a handful of workers must decode the full
    /// product, and the recovered values must match the true
    /// sub-products.
    #[test]
    fn rateless_stream_decodes_to_the_true_product() {
        let (part, cm) = paper_setup();
        let mut mrng = Pcg64::seed_from(42);
        let a = Matrix::randn(part.a_shape().0, part.a_shape().1, 0.0, 1.0, &mut mrng);
        let b = Matrix::randn(part.b_shape().0, part.b_shape().1, 0.0, 1.0, &mut mrng);
        let a_blocks = part.split_a(&a);
        let b_blocks = part.split_b(&b);
        let truth = part.true_products(&a, &b);
        let spec = RatelessSpec::paper_default();
        let coder = RatelessCoder::from_class_map(&spec, &cm);
        let space = UnknownSpace::for_code(&part, crate::coding::EncodeStyle::Stacked);
        let mut st = DecodeState::new(space);
        'outer: for seq in 0..200u32 {
            for stream in 0..3u64 {
                let p = coder.packet(99, stream, seq);
                let (wa, wb) = crate::coordinator::build_job_matrices(
                    &part, &a_blocks, &b_blocks, &p.recipe,
                );
                st.add_packet(&p, Some(matmul(&wa, &wb)));
                if st.is_complete() {
                    break 'outer;
                }
            }
        }
        assert!(st.is_complete(), "stream never decoded");
        for (u, v) in st.recover_values().into_iter().enumerate() {
            let got = v.expect("complete decode must value every unknown");
            assert!(got.allclose(&truth[u], 1e-6), "unknown {u} wrong");
        }
    }

    /// Satellite property: the UEP degree distribution includes class-0
    /// unknowns at least as often as class-L unknowns — class 0 belongs
    /// to every expanding window, the last class only to the widest.
    #[test]
    fn class0_unknowns_are_sampled_at_least_as_often_as_class_last() {
        prop_check(
            "class-0 inclusion dominates class-L",
            PropConfig { cases: 8, seed: 714 },
            |rng, _case| {
                let (_, cm) = paper_setup();
                // random (positive) window weights each case
                let weights: Vec<f64> =
                    (0..3).map(|_| 0.05 + rng.next_f64()).collect();
                let spec = RatelessSpec::new(
                    0.01 + 0.5 * rng.next_f64(),
                    0.02 + 0.5 * rng.next_f64(),
                    WindowPolynomial::new(&weights),
                );
                let coder = RatelessCoder::from_class_map(&spec, &cm);
                let request = gen::usize_in(rng, 1, 1 << 30) as u64;
                let mut hits = vec![0usize; cm.class_of.len()];
                let n = 1200u32;
                for seq in 0..n {
                    let p = coder.packet(request, 0, seq);
                    if let JobRecipe::Stacked { terms } = &p.recipe {
                        for t in terms {
                            hits[t.unknown] += 1;
                        }
                    }
                }
                let mean_hits = |class: usize| {
                    let members = &cm.members[class];
                    members.iter().map(|&u| hits[u]).sum::<usize>() as f64
                        / members.len() as f64
                };
                let c0 = mean_hits(0);
                let cl = mean_hits(cm.n_classes - 1);
                // allow a small sampling slack; the expectation gap is
                // strict whenever Γ puts any mass below the last window
                if c0 + 3.0 * (c0.max(1.0)).sqrt() < cl {
                    return Err(format!("class0 mean {c0} < classL mean {cl}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn coder_resizes_gamma_to_the_class_count() {
        // 2-class map with the 3-window paper polynomial must not panic
        let part = Partitioning::cxr(4, 2, 2, 2);
        let lv = vec![0, 0, 2, 2];
        let pair = default_pair_classes(3);
        let cm = ClassMap::from_levels(&part, lv.clone(), lv, &pair);
        assert_eq!(cm.n_classes, 2);
        let coder = RatelessCoder::from_class_map(&RatelessSpec::paper_default(), &cm);
        assert_eq!(coder.gamma().num_windows(), 2);
        for seq in 0..50 {
            let p = coder.packet(3, 0, seq);
            assert!(p.window < 2);
        }
    }
}
