//! Coding layer (paper §III-C, §IV): Unequal Error Protection random
//! linear codes over the matrix sub-products, plus the baselines the
//! paper compares against.
//!
//! Schemes:
//! * **NOW-UEP** — non-overlapping windows: each packet protects exactly
//!   one importance class, chosen from the window polynomial `Γ(ξ)`.
//! * **EW-UEP** — expanding windows: a packet for window `l` protects
//!   classes `1..l`, so the most important class appears in every packet.
//! * **MDS** — dense random linear code over all sub-products (real
//!   Gaussian coefficients are MDS with probability 1).
//! * **Repetition** — each sub-product replicated `⌈W/K⌉` times.
//! * **Uncoded** — one worker per sub-product.
//! * **Rateless UEP** — LT/fountain packets with a robust-Soliton degree
//!   distribution over expanding windows sampled from `Γ(ξ)`; no fixed
//!   `n`, packets derived deterministically per `(request, stream, seq)`
//!   so both ends of a connection generate identical coefficient rows
//!   (see [`RatelessCoder`]).
//!
//! Encoding styles (see DESIGN.md §2 — the paper under-specifies this):
//! * [`EncodeStyle::Stacked`] — exact RLC via block concatenation: the
//!   packet `Σ_j c_j·A_{n_j}B_{p_j}` is computed as the single product
//!   `[c₁A_{n₁}, …] · [B_{p₁}; …]`. Matches the paper's analysis.
//! * [`EncodeStyle::RankOne`] — the paper's literal eq. (17):
//!   `(Σ_i α_i A_i)(Σ_j β_j B_j)`; packets carry Khatri-Rao coefficients
//!   over all cross products, including "ghost" terms (c×r off-diagonal
//!   pairs) that are not part of `C`.

mod decode;
mod rateless;
mod scheme;
mod window;

pub use decode::DecodeState;
pub use rateless::{robust_soliton, RatelessCoder, RatelessSpec, UepWindows};
pub use scheme::{
    CodeKind, CodeSpec, EncodeStyle, JobRecipe, Packet, StackTerm, UnknownSpace,
};
pub use window::WindowPolynomial;

/// A trait alias-style facade: anything that can generate the packet set
/// for `W` workers given a partitioning and class map.
pub trait Code {
    fn packets(
        &self,
        part: &crate::partition::Partitioning,
        cm: &crate::partition::ClassMap,
        workers: usize,
        rng: &mut crate::rng::Pcg64,
    ) -> Vec<Packet>;
}

impl Code for CodeSpec {
    fn packets(
        &self,
        part: &crate::partition::Partitioning,
        cm: &crate::partition::ClassMap,
        workers: usize,
        rng: &mut crate::rng::Pcg64,
    ) -> Vec<Packet> {
        self.generate_packets(part, cm, workers, rng)
    }
}
