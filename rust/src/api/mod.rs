//! The unified client API: one front door to every execution path.
//!
//! Historically the crate exposed three incompatible entry points —
//! `Coordinator::run` (in-process virtual time), the threaded service
//! shim, and `ClusterServer` (networked) — each with its own config,
//! outcome shape, and error conventions. This module is the single
//! public surface that replaces them:
//!
//! * [`Backend`] — `submit` / `poll` / `cancel` plus [`Capabilities`]
//!   flags, with [`InProcessBackend`], [`PooledBackend`], and
//!   [`ClusterBackend`] adapters wrapping the three paths;
//! * [`Session`] — a builder-validated plan (partitioning, code,
//!   classes, workers, latency, deadline) bound to one backend, owning
//!   the encoded-block cache so a repeated-`A` stream pays one encode;
//! * [`RequestHandle`]s with batched/pipelined submission
//!   ([`Session::submit_batch`]);
//! * [`Progress`] — the anytime stream: one event per decode
//!   refinement (`recovered`, running loss, elapsed), so callers
//!   consume `Ĉ(t)` as results trickle in rather than only the final
//!   outcome;
//! * [`Replanner`] / [`ReplanPolicy`] — the straggle-adaptive planning
//!   loop ([`SessionBuilder::adaptive`]): per-job timing telemetry
//!   ([`RunReport::timings`]) feeds a fitted latency model, which feeds
//!   [`crate::analysis::optimize_gamma`], which re-tunes the window
//!   polynomial between requests;
//! * [`UepmmError`] — typed errors at the boundary (`anyhow` stays
//!   internal).
//!
//! The backend-equivalence guarantee: the same seed and session
//! configuration produce a bit-identical [`crate::coordinator::Outcome`]
//! on every deterministic backend (asserted by
//! `rust/tests/api_backends.rs`).
//!
//! # Example
//!
//! A scored multiplication over the loopback worker pool:
//!
//! ```
//! use uepmm::prelude::*;
//!
//! # fn main() -> Result<(), UepmmError> {
//! let mut rng = Pcg64::seed_from(1);
//! let part = Partitioning::rxc(3, 3, 4, 5, 4);
//! let a = Matrix::randn(12, 5, 0.0, 1.0, &mut rng);
//! let b = Matrix::randn(5, 12, 0.0, 1.0, &mut rng);
//!
//! let mut session = Session::builder()
//!     .partitioning(part)
//!     .code(CodeSpec::stacked(CodeKind::Mds))
//!     .workers(12)
//!     .latency(LatencyModel::exp(1.0))
//!     .deadline(50.0)
//!     .score(true)
//!     .seed(7)
//!     .backend(PooledBackend::spawn(2)?)
//!     .build()?;
//!
//! let report = session.run(Request::new(0, a, b))?;
//! assert_eq!(report.outcome.recovered, 9); // MDS: any ≥9 packets decode all
//! assert!(report.outcome.normalized_loss < 1e-9);
//! assert!(report.progress.loss_non_increasing());
//! session.shutdown()?;
//! # Ok(())
//! # }
//! ```
//!
//! # Adaptive example
//!
//! The same stream with the adaptive planning loop switched on: the
//! session observes every request's per-job timings, and once the
//! policy's cadence is reached it fits a latency model to them and
//! re-optimizes the EW window polynomial — the decision shows up as a
//! replan event in the next request's progress stream.
//!
//! ```
//! use uepmm::prelude::*;
//! use uepmm::api::ReplanPolicy;
//!
//! # fn main() -> Result<(), UepmmError> {
//! let mut rng = Pcg64::seed_from(2);
//! let part = Partitioning::rxc(3, 3, 4, 5, 4);
//! let pair = uepmm::partition::default_pair_classes(3);
//! let cm = ClassMap::from_levels(&part, vec![0, 1, 2], vec![0, 1, 2], &pair);
//! let a = Matrix::randn(12, 5, 0.0, 1.0, &mut rng);
//!
//! let mut session = Session::builder()
//!     .partitioning(part)
//!     .code(CodeSpec::stacked(CodeKind::EwUep(WindowPolynomial::paper_table3())))
//!     .classes(cm)
//!     .workers(12)
//!     .latency(LatencyModel::exp(1.0)) // the *assumed* model
//!     .deadline(1.0)
//!     .seed(7)
//!     .adaptive(ReplanPolicy { every: 2, min_samples: 4, ..Default::default() })
//!     .backend(InProcessBackend::serial())
//!     .build()?;
//!
//! let mut replans = 0;
//! for _ in 0..6 {
//!     let b = Matrix::randn(5, 12, 0.0, 1.0, &mut rng);
//!     let report = session.run(Request::new(0, a.clone(), b))?;
//!     replans += report.progress.replans().len();
//! }
//! assert!(replans >= 1, "the cadence must have triggered a replan");
//! assert_eq!(session.replan_count(), replans);
//! assert!(session.fitted_latency().is_some());
//! # Ok(())
//! # }
//! ```

mod adapt;
mod backend;
mod error;
mod progress;
mod session;

pub use adapt::{
    class_sigma2_from_norms, estimate_class_sigma2, ReplanEvent, ReplanPolicy,
    Replanner,
};
pub use backend::{
    Backend, Capabilities, ClusterBackend, InProcessBackend, Maintenance,
    PollState, PooledBackend, SharedBackend,
};
pub use error::{ApiResult, UepmmError};
pub use progress::{Progress, ProgressEvent};
pub use session::{
    Classes, Compute, OmegaMode, PreparedRequest, PreparedWork, Request,
    RequestHandle, RunReport, ScoreRef, Session, SessionBuilder,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodeKind, CodeSpec};
    use crate::latency::LatencyModel;
    use crate::partition::Partitioning;

    fn base_builder() -> SessionBuilder {
        Session::builder()
            .partitioning(Partitioning::rxc(3, 3, 2, 3, 2))
            .code(CodeSpec::stacked(CodeKind::Mds))
            .workers(6)
            .latency(LatencyModel::exp(1.0))
            .deadline(1.0)
    }

    #[test]
    fn builder_rejects_missing_pieces_with_typed_errors() {
        let e = Session::builder().build().unwrap_err();
        assert!(matches!(e, UepmmError::Config(_)), "{e}");

        let e = base_builder().build().unwrap_err();
        assert!(matches!(e, UepmmError::Config(_)), "no backend: {e}");

        let e = base_builder()
            .workers(0)
            .backend(InProcessBackend::serial())
            .build()
            .unwrap_err();
        assert!(matches!(e, UepmmError::Config(_)), "{e}");

        let e = base_builder()
            .deadline(f64::NAN)
            .backend(InProcessBackend::serial())
            .build()
            .unwrap_err();
        assert!(matches!(e, UepmmError::Deadline(_)), "{e}");
    }

    #[test]
    fn builder_enforces_backend_capabilities() {
        // the in-process backend replays virtual delays: a latency
        // model is mandatory
        let e = Session::builder()
            .partitioning(Partitioning::rxc(3, 3, 2, 3, 2))
            .code(CodeSpec::stacked(CodeKind::Mds))
            .workers(6)
            .deadline(1.0)
            .backend(InProcessBackend::serial())
            .build()
            .unwrap_err();
        assert!(matches!(e, UepmmError::Config(_)), "{e}");

        // selective compute is in-process only
        let e = base_builder()
            .compute(Compute::Selective)
            .backend(PooledBackend::spawn(1).unwrap())
            .build()
            .unwrap_err();
        assert!(matches!(e, UepmmError::Config(_)), "{e}");
    }

    #[test]
    fn builder_rejects_incoherent_class_maps() {
        let other = Partitioning::rxc(2, 2, 2, 3, 2);
        let pair = crate::partition::default_pair_classes(2);
        let cm = crate::partition::ClassMap::from_levels(
            &other,
            vec![0, 1],
            vec![0, 1],
            &pair,
        );
        let e = base_builder()
            .classes(cm)
            .backend(InProcessBackend::serial())
            .build()
            .unwrap_err();
        assert!(matches!(e, UepmmError::Config(_)), "{e}");
    }

    #[test]
    fn submit_rejects_shape_mismatches() {
        let mut rng = crate::rng::Pcg64::seed_from(3);
        let mut session = base_builder()
            .backend(InProcessBackend::serial())
            .build()
            .unwrap();
        let a_bad = crate::linalg::Matrix::randn(5, 3, 0.0, 1.0, &mut rng);
        let b = crate::linalg::Matrix::randn(3, 6, 0.0, 1.0, &mut rng);
        let e = session.submit(Request::new(0, a_bad, b)).unwrap_err();
        assert!(matches!(e, UepmmError::Config(_)), "{e}");
    }

    #[test]
    fn polling_an_unknown_handle_is_a_config_error() {
        let mut session = base_builder()
            .backend(InProcessBackend::serial())
            .build()
            .unwrap();
        let e = session.poll(RequestHandle { id: 99 }).unwrap_err();
        assert!(matches!(e, UepmmError::Config(_)), "{e}");
    }

    fn rateless_builder() -> SessionBuilder {
        use crate::coding::RatelessSpec;
        Session::builder()
            .partitioning(Partitioning::rxc(3, 3, 4, 5, 4))
            .code(CodeSpec::stacked(CodeKind::Rateless(RatelessSpec::paper_default())))
            .workers(4)
            .latency(LatencyModel::exp(1.0))
            .deadline(100.0)
            .score(true)
            .seed(7)
    }

    fn rateless_operands() -> (crate::linalg::Matrix, crate::linalg::Matrix) {
        let mut rng = crate::rng::Pcg64::seed_from(11);
        let a = crate::linalg::Matrix::randn(12, 5, 0.0, 1.0, &mut rng);
        let b = crate::linalg::Matrix::randn(5, 12, 0.0, 1.0, &mut rng);
        (a, b)
    }

    #[test]
    fn rateless_session_decodes_exactly_and_reruns_bit_identically() {
        let run = || {
            let (a, b) = rateless_operands();
            let mut session = rateless_builder()
                .backend(InProcessBackend::serial())
                .build()
                .unwrap();
            session.run(Request::new(0, a, b)).unwrap()
        };
        let x = run();
        assert_eq!(x.outcome.recovered, 9);
        assert!(x.outcome.normalized_loss < 1e-9, "{}", x.outcome.normalized_loss);
        assert!(x.cache_hit.is_none(), "rateless requests bypass the encode cache");
        assert_eq!(x.worker_packets.len(), 4);
        let credited: usize = x.worker_packets.iter().map(|&(_, c)| c).sum();
        assert_eq!(credited, x.outcome.received);
        assert_eq!(x.dispatched, x.outcome.received, "stream stops at completion");
        assert!(x.progress.loss_non_increasing());
        let y = run();
        assert_eq!(x.outcome.c_hat.data(), y.outcome.c_hat.data());
        assert_eq!(x.outcome.received, y.outcome.received);
        assert_eq!(x.partial_packets, y.partial_packets);
    }

    #[test]
    fn rateless_straggler_stream_earns_partial_credit_in_process() {
        // three fast streams carry only two packets each (6 < 9
        // unknowns), so the decode cannot finish without the straggler's
        // slow-but-steady stream
        let schedules = vec![
            vec![0.1, 0.2],
            vec![0.1, 0.2],
            vec![0.1, 0.2],
            (1..=60).map(|k| k as f64).collect(),
        ];
        let (a, b) = rateless_operands();
        let mut session = rateless_builder()
            .deadline(1000.0)
            .backend(InProcessBackend::serial())
            .build()
            .unwrap();
        let report =
            session.run(Request::new(0, a, b).schedules(schedules)).unwrap();
        assert_eq!(report.outcome.recovered, 9);
        assert!(report.outcome.normalized_loss < 1e-9);
        assert!(report.partial_packets > 0, "slowest stream must be credited");
        assert!(report.worker_packets[3].1 >= 3, "{:?}", report.worker_packets);
    }

    #[test]
    fn rateless_session_over_pooled_backend_decodes_exactly() {
        let schedules = vec![
            vec![0.1, 0.2],
            vec![0.1, 0.2],
            vec![0.1, 0.2],
            (1..=60).map(|k| k as f64).collect(),
        ];
        let (a, b) = rateless_operands();
        let mut session = rateless_builder()
            .deadline(1000.0)
            .backend(PooledBackend::spawn(4).unwrap())
            .build()
            .unwrap();
        let report =
            session.run(Request::new(0, a, b).schedules(schedules)).unwrap();
        assert_eq!(report.outcome.recovered, 9);
        assert!(report.outcome.normalized_loss < 1e-9);
        assert!(report.partial_packets > 0);
        assert_eq!(report.verify_failures, 0);
        session.shutdown().unwrap();
    }

    #[test]
    fn rateless_misuse_is_rejected_with_config_errors() {
        // schedules on a fixed-rate code
        let (a, b) = rateless_operands();
        let mut fixed = Session::builder()
            .partitioning(Partitioning::rxc(3, 3, 4, 5, 4))
            .code(CodeSpec::stacked(CodeKind::Mds))
            .workers(4)
            .latency(LatencyModel::exp(1.0))
            .deadline(10.0)
            .backend(InProcessBackend::serial())
            .build()
            .unwrap();
        let e = fixed
            .submit(Request::new(0, a.clone(), b.clone()).schedules(vec![vec![]; 4]))
            .unwrap_err();
        assert!(matches!(e, UepmmError::Config(_)), "{e}");

        // selective compute under a rateless code
        let mut sel = rateless_builder()
            .compute(Compute::Selective)
            .backend(InProcessBackend::serial())
            .build()
            .unwrap();
        let e = sel.submit(Request::new(0, a.clone(), b.clone())).unwrap_err();
        assert!(matches!(e, UepmmError::Config(_)), "{e}");

        // wrong schedule count
        let mut rl = rateless_builder()
            .backend(InProcessBackend::serial())
            .build()
            .unwrap();
        let e = rl
            .submit(Request::new(0, a, b).schedules(vec![vec![0.5]; 3]))
            .unwrap_err();
        assert!(matches!(e, UepmmError::Config(_)), "{e}");
    }
}
