//! The [`Session`]: one validated client-side plan — partitioning, code,
//! classes, worker count, latency/deadline discipline — bound to one
//! [`Backend`], owning the encoded-block cache and the request-id space.
//!
//! A session is built once ([`Session::builder`]) and then serves a
//! stream of [`Request`]s. Preparation (split, classify, packet draw,
//! `W_A` materialization) happens on the session so that *every*
//! backend — in-process, pooled, networked — reuses cached `A`-side
//! encodings across a repeated-`A` stream; backends only execute and
//! decode.

use std::sync::Arc;
use std::time::Duration;

use crate::cluster::{CacheKey, CacheStats, EncodedBlockCache};
use crate::coding::{CodeSpec, Packet, UnknownSpace};
use crate::coordinator::{EncodedA, Outcome};
use crate::latency::LatencyModel;
use crate::linalg::Matrix;
use crate::partition::{ClassMap, Partitioning};
use crate::rng::Pcg64;

use super::backend::{Backend, Maintenance, PollState};
use super::error::{ApiResult, UepmmError};
use super::progress::Progress;

/// One multiplication request in a session's stream. `a_id` is the
/// caller's stable identity for `A` (e.g. "layer-3 weights"): requests
/// sharing an `a_id` share cached encodings.
#[derive(Clone, Debug)]
pub struct Request {
    pub a_id: u64,
    pub a: Matrix,
    pub b: Matrix,
    /// Per-request deadline override (defaults to the session deadline).
    pub t_max: Option<f64>,
    /// Per-request scoring override (defaults to the session setting).
    pub score: Option<bool>,
}

impl Request {
    pub fn new(a_id: u64, a: Matrix, b: Matrix) -> Request {
        Request { a_id, a, b, t_max: None, score: None }
    }

    /// Override the session deadline for this request.
    pub fn deadline(mut self, t_max: f64) -> Request {
        self.t_max = Some(t_max);
        self
    }

    /// Override the session's scoring setting for this request.
    pub fn scored(mut self, score: bool) -> Request {
        self.score = Some(score);
        self
    }
}

/// Handle to a submitted request; redeem it with [`Session::poll`] /
/// [`Session::wait`] or abandon it with [`Session::cancel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RequestHandle {
    pub id: u64,
}

/// The unified result of one served request, across every backend.
///
/// This supersedes the per-path result shapes (`Outcome` alone from
/// `Coordinator::run`, `ServiceOutcome` from `run_service`,
/// `ClusterOutcome` from `ClusterServer`): the decode [`Outcome`] plus
/// the accounting every path shares, plus the anytime [`Progress`]
/// stream.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Decode result: received/recovered counts, `Ĉ`, loss (NaN when
    /// the request was not scored).
    pub outcome: Outcome,
    /// Results that were computed but missed the deadline.
    pub late: usize,
    /// Jobs handed to the execution path.
    pub dispatched: usize,
    /// Re-dispatches of jobs stranded on workers that died mid-request
    /// (cluster-backed paths; always 0 in-process). A failure costs
    /// latency, not work: retried slots still land as `received`.
    pub retries: usize,
    /// Result frames naming a slot outside the request's job set (a
    /// broken worker; the sender is evicted and its work re-dispatched).
    pub corrupt: usize,
    /// Wall time the request took end to end.
    pub wall: Duration,
    /// `Some(hit)` when served through the session's encoded-block
    /// cache (`None` in selective-compute mode, which skips `W_A`).
    pub cache_hit: Option<bool>,
    /// Name of the backend that served the request.
    pub backend: &'static str,
    /// The recorded refinement stream (one event per absorbed
    /// in-deadline result).
    pub progress: Progress,
}

impl RunReport {
    /// Dispatched jobs whose results were never seen: slots written off
    /// after exhausting their re-dispatch budget (every holder died),
    /// and post-grace stragglers in wall-deadline mode.
    pub fn missing(&self) -> usize {
        self.dispatched - self.outcome.received - self.late
    }
}

/// Scoring reference for one request: the exact product and its Gram
/// matrix, computed locally. Evaluation only — production streams skip
/// it (`score = false`) because the local `A·B` dwarfs dispatch+decode.
#[derive(Clone, Debug)]
pub struct ScoreRef {
    /// The exact product `A·B`.
    pub c_true: Matrix,
    /// Gram matrix `G_ij = ⟨C_i, C_j⟩_F` of the true sub-products
    /// (drives the running progress loss).
    pub gram: Matrix,
    /// `‖C‖²_F` read off the Gram matrix.
    pub energy: f64,
}

/// The work a backend receives for one request, fully prepared by the
/// session.
#[derive(Clone, Debug)]
pub enum PreparedWork {
    /// Materialized per-worker factor pairs: `wa` handles from the
    /// (possibly cached) [`EncodedA`], plus this request's freshly
    /// bound right factors. Honest compute: workers multiply exactly
    /// these.
    Encoded { enc: Arc<EncodedA>, wb: Vec<Matrix> },
    /// Coefficient-only decode over the raw block split; recovered
    /// sub-products are then computed exactly and directly. The
    /// training fast path (`W_A` is never materialized) — in-process
    /// backends only.
    Blocks {
        space: UnknownSpace,
        packets: Vec<Packet>,
        a_blocks: Vec<Matrix>,
        b_blocks: Vec<Matrix>,
    },
}

/// One fully prepared request as handed to a [`Backend`].
#[derive(Clone, Debug)]
pub struct PreparedRequest {
    pub id: u64,
    pub part: Partitioning,
    pub cm: ClassMap,
    /// Deadline in virtual time units.
    pub t_max: f64,
    /// Pre-sampled virtual completion times, one per packet (absent
    /// when the session has no latency model: timing is then up to the
    /// workers/transport).
    pub delays: Option<Vec<f64>>,
    pub work: PreparedWork,
    pub score: Option<ScoreRef>,
    /// Whether the `A`-side came out of the session cache.
    pub cache_hit: Option<bool>,
}

impl PreparedRequest {
    /// Coded jobs (= packets) in this request.
    pub fn jobs(&self) -> usize {
        match &self.work {
            PreparedWork::Encoded { enc, .. } => enc.packets.len(),
            PreparedWork::Blocks { packets, .. } => packets.len(),
        }
    }
}

/// How sub-products are classified into importance levels.
#[derive(Clone, Debug)]
pub enum Classes {
    /// Estimate per request from the operands' block norms (`S` levels).
    Auto(usize),
    /// Pinned assignment (synthetic experiments, coherent cache keys).
    Pinned(ClassMap),
}

/// The paper's Ω capacity scaling (Remark 1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OmegaMode {
    /// `Ω = #sub-products / workers`, recomputed from the session plan.
    Auto,
    Fixed(f64),
}

/// How worker payloads are produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compute {
    /// Materialize `W_A`/`W_B` and multiply them — what real workers do.
    Honest,
    /// Coefficient-only decode, then compute only the recovered
    /// sub-products exactly (the training fast path; in-process only).
    Selective,
}

/// Builder for [`Session`]; validates the full plan up front so a
/// misconfigured stream fails at [`SessionBuilder::build`], not on
/// request `N`.
pub struct SessionBuilder {
    part: Option<Partitioning>,
    spec: Option<CodeSpec>,
    classes: Classes,
    workers: Option<usize>,
    latency: Option<LatencyModel>,
    omega: OmegaMode,
    deadline: Option<f64>,
    score: bool,
    compute: Compute,
    cache_capacity: usize,
    seed: u64,
    backend: Option<Box<dyn Backend>>,
}

impl SessionBuilder {
    fn new() -> SessionBuilder {
        SessionBuilder {
            part: None,
            spec: None,
            classes: Classes::Auto(3),
            workers: None,
            latency: None,
            omega: OmegaMode::Auto,
            deadline: None,
            score: false,
            compute: Compute::Honest,
            cache_capacity: 16,
            seed: 0,
            backend: None,
        }
    }

    /// Block partitioning of the operands (paper §II).
    pub fn partitioning(mut self, part: Partitioning) -> Self {
        self.part = Some(part);
        self
    }

    /// The fully specified code (kind + encoding style).
    pub fn code(mut self, spec: CodeSpec) -> Self {
        self.spec = Some(spec);
        self
    }

    /// Pin the importance-class assignment.
    pub fn classes(mut self, cm: ClassMap) -> Self {
        self.classes = Classes::Pinned(cm);
        self
    }

    /// Classify per request from block norms into `s_levels` levels.
    ///
    /// Note: an auto class map depends on each request's `B`, so the
    /// encoded-block cache cannot apply — repeated-`A` streams that
    /// want cache hits must pin their classes with
    /// [`Self::classes`].
    pub fn auto_classes(mut self, s_levels: usize) -> Self {
        self.classes = Classes::Auto(s_levels);
        self
    }

    /// Coded packets (= jobs) per request.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers);
        self
    }

    /// Straggle model used to pre-sample virtual completion times.
    pub fn latency(mut self, model: LatencyModel) -> Self {
        self.latency = Some(model);
        self
    }

    /// Ω capacity scaling mode (default: auto, per Remark 1).
    pub fn omega(mut self, omega: OmegaMode) -> Self {
        self.omega = omega;
        self
    }

    /// Default per-request deadline `T_max` (virtual time units).
    pub fn deadline(mut self, t_max: f64) -> Self {
        self.deadline = Some(t_max);
        self
    }

    /// Score every request against the locally computed exact product
    /// (evaluation streams; default off).
    pub fn score(mut self, score: bool) -> Self {
        self.score = score;
        self
    }

    /// Payload production mode (default honest).
    pub fn compute(mut self, compute: Compute) -> Self {
        self.compute = compute;
        self
    }

    /// Encoded-block cache capacity in entries (0 disables caching).
    pub fn cache_capacity(mut self, entries: usize) -> Self {
        self.cache_capacity = entries;
        self
    }

    /// Seed of the session RNG (packet draws + delay sampling).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// The execution backend serving this session.
    pub fn backend(mut self, backend: impl Backend + 'static) -> Self {
        self.backend = Some(Box::new(backend));
        self
    }

    /// Validate the full plan and assemble the session.
    pub fn build(self) -> ApiResult<Session> {
        let part = self
            .part
            .ok_or_else(|| UepmmError::Config("no partitioning set".to_string()))?;
        let spec = self
            .spec
            .ok_or_else(|| UepmmError::Config("no code spec set".to_string()))?;
        let backend = self
            .backend
            .ok_or_else(|| UepmmError::Config("no backend set".to_string()))?;
        let workers = self
            .workers
            .ok_or_else(|| UepmmError::Config("no worker count set".to_string()))?;
        if workers == 0 {
            return Err(UepmmError::Config("need at least one worker".to_string()));
        }
        let deadline = self
            .deadline
            .ok_or_else(|| UepmmError::Config("no deadline set".to_string()))?;
        validate_deadline(deadline)?;
        match &self.classes {
            Classes::Auto(s) if *s == 0 => {
                return Err(UepmmError::Config(
                    "need at least one importance level".to_string(),
                ))
            }
            Classes::Pinned(cm) if cm.class_of.len() != part.num_products() => {
                return Err(UepmmError::Config(format!(
                    "class map covers {} sub-products, partitioning has {}",
                    cm.class_of.len(),
                    part.num_products()
                )))
            }
            _ => {}
        }
        let caps = backend.capabilities();
        if caps.needs_injected_delays && self.latency.is_none() {
            return Err(UepmmError::Config(format!(
                "backend '{}' replays pre-sampled virtual delays; set a latency model",
                backend.name()
            )));
        }
        if self.compute == Compute::Selective && !caps.selective_compute {
            return Err(UepmmError::Config(format!(
                "backend '{}' cannot run selective (coefficient-only) compute",
                backend.name()
            )));
        }
        Ok(Session {
            part,
            spec,
            classes: self.classes,
            workers,
            latency: self.latency,
            omega: self.omega,
            deadline,
            score: self.score,
            compute: self.compute,
            rng: Pcg64::seed_from(self.seed),
            cache: EncodedBlockCache::new(self.cache_capacity),
            backend,
            next_id: 1,
        })
    }
}

fn validate_deadline(t_max: f64) -> ApiResult<()> {
    if !t_max.is_finite() || t_max < 0.0 {
        return Err(UepmmError::Deadline(format!(
            "T_max must be finite and non-negative, got {t_max}"
        )));
    }
    Ok(())
}

/// One validated client plan bound to one backend. See module docs.
pub struct Session {
    part: Partitioning,
    spec: CodeSpec,
    classes: Classes,
    workers: usize,
    latency: Option<LatencyModel>,
    omega: OmegaMode,
    deadline: f64,
    score: bool,
    compute: Compute,
    rng: Pcg64,
    cache: EncodedBlockCache,
    backend: Box<dyn Backend>,
    next_id: u64,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    pub fn partitioning(&self) -> &Partitioning {
        &self.part
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The effective Ω capacity scaling.
    pub fn omega_value(&self) -> f64 {
        match self.omega {
            OmegaMode::Auto => {
                crate::latency::omega(self.part.num_products(), self.workers)
            }
            OmegaMode::Fixed(w) => w,
        }
    }

    /// Hit/miss/eviction counters of the session's encoded-block cache.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Prepare and enqueue one request; returns immediately with a
    /// handle. Backends pipeline queued requests in submission order.
    pub fn submit(&mut self, req: Request) -> ApiResult<RequestHandle> {
        let prep = self.prepare(req)?;
        let id = prep.id;
        self.backend.submit(prep)?;
        Ok(RequestHandle { id })
    }

    /// Batched submission: prepare the whole stream (so a repeated-`A`
    /// stream pays one encode and `N−1` cache hits up front) and hand
    /// every request to the backend before any result is awaited.
    pub fn submit_batch(
        &mut self,
        reqs: impl IntoIterator<Item = Request>,
    ) -> ApiResult<Vec<RequestHandle>> {
        let mut handles = Vec::new();
        for req in reqs {
            handles.push(self.submit(req)?);
        }
        Ok(handles)
    }

    /// One poll step. `Pending` carries the refinement events recorded
    /// since the last poll (streaming backends absorb one arrival per
    /// poll); `Ready` consumes the handle and yields the full report.
    pub fn poll(&mut self, h: RequestHandle) -> ApiResult<PollState> {
        self.backend.poll(h.id)
    }

    /// Drive the backend until the request completes.
    pub fn wait(&mut self, h: RequestHandle) -> ApiResult<RunReport> {
        loop {
            match self.backend.poll(h.id)? {
                PollState::Ready(report) => return Ok(report),
                PollState::Pending(_) => {}
            }
        }
    }

    /// `submit` + `wait` in one call.
    pub fn run(&mut self, req: Request) -> ApiResult<RunReport> {
        let h = self.submit(req)?;
        self.wait(h)
    }

    /// Cancel a request. An in-flight streaming request finalizes with
    /// whatever it decoded so far (the anytime contract) — `Some`
    /// carries that partial report; `None` means the request was
    /// dropped before any work happened (or the handle was unknown).
    pub fn cancel(&mut self, h: RequestHandle) -> ApiResult<Option<RunReport>> {
        self.backend.cancel(h.id)
    }

    /// Backend upkeep between requests: heartbeat/evict dead workers on
    /// networked backends, a no-op elsewhere.
    pub fn maintain(&mut self) -> ApiResult<Maintenance> {
        self.backend.maintain()
    }

    /// Orderly teardown of the backend (graceful worker shutdown on
    /// cluster backends).
    pub fn shutdown(mut self) -> ApiResult<()> {
        self.backend.shutdown()
    }

    // ---------------------------------------------------------- prepare

    fn prepare(&mut self, req: Request) -> ApiResult<PreparedRequest> {
        if req.a.shape() != self.part.a_shape() {
            return Err(UepmmError::Config(format!(
                "A is {:?}, partitioning expects {:?}",
                req.a.shape(),
                self.part.a_shape()
            )));
        }
        if req.b.shape() != self.part.b_shape() {
            return Err(UepmmError::Config(format!(
                "B is {:?}, partitioning expects {:?}",
                req.b.shape(),
                self.part.b_shape()
            )));
        }
        let t_max = req.t_max.unwrap_or(self.deadline);
        validate_deadline(t_max)?;
        let cm = match &self.classes {
            Classes::Pinned(cm) => cm.clone(),
            Classes::Auto(s) => ClassMap::from_matrices(&self.part, &req.a, &req.b, *s),
        };
        let score = req.score.unwrap_or(self.score);
        let score_ref = if score {
            // one pass over the sub-products serves both references: the
            // Gram matrix for the running progress loss, and the exact
            // product (assembled from the same blocks, both paradigms)
            // for the final honest score — no second full matmul
            let products = self.part.true_products(&req.a, &req.b);
            let gram = self.part.gram(&products);
            let energy = self
                .part
                .loss_from_gram(&gram, &vec![false; self.part.num_products()]);
            let c_true = self
                .part
                .assemble(&products.into_iter().map(Some).collect::<Vec<_>>());
            Some(ScoreRef { c_true, gram, energy })
        } else {
            None
        };
        let (work, cache_hit) = match self.compute {
            Compute::Honest => {
                // the cache is only coherent under pinned classes: an
                // auto class map depends on each request's B, so its
                // entries could never be shared across a stream — build
                // the encoding directly (and retain nothing) instead of
                // silently filling the cache with dead entries
                let cacheable = matches!(self.classes, Classes::Pinned(_));
                let (enc, hit) = if cacheable {
                    let key = CacheKey::new(
                        req.a_id,
                        &self.part,
                        &self.spec,
                        &cm,
                        self.workers,
                    );
                    let part = &self.part;
                    let spec = &self.spec;
                    let workers = self.workers;
                    let rng = &mut self.rng;
                    let (enc, hit) = self
                        .cache
                        .get_or_insert_with(key, || {
                            EncodedA::encode(
                                part,
                                spec.clone(),
                                &cm,
                                workers,
                                &req.a,
                                rng,
                            )
                        })
                        .map_err(|e| UepmmError::Encode(format!("{e:#}")))?;
                    (enc, Some(hit))
                } else {
                    let enc = EncodedA::encode(
                        &self.part,
                        self.spec.clone(),
                        &cm,
                        self.workers,
                        &req.a,
                        &mut self.rng,
                    )
                    .map_err(|e| UepmmError::Encode(format!("{e:#}")))?;
                    (Arc::new(enc), None)
                };
                let b_blocks = self.part.split_b(&req.b);
                let wb: Vec<Matrix> =
                    (0..enc.workers()).map(|w| enc.job_b(&b_blocks, w)).collect();
                (PreparedWork::Encoded { enc, wb }, hit)
            }
            Compute::Selective => {
                // no W_A materialization and no caching: the training
                // shape changes A every call, so cached encodings would
                // never be coherent anyway
                let a_blocks = self.part.split_a(&req.a);
                let b_blocks = self.part.split_b(&req.b);
                let packets = self.spec.generate_packets(
                    &self.part,
                    &cm,
                    self.workers,
                    &mut self.rng,
                );
                let space = UnknownSpace::for_code(&self.part, self.spec.style);
                (
                    PreparedWork::Blocks { space, packets, a_blocks, b_blocks },
                    None,
                )
            }
        };
        let omega = self.omega_value();
        let delays = match self.latency.clone() {
            Some(model) => {
                let mut d = Vec::with_capacity(self.workers);
                for _ in 0..self.workers {
                    d.push(model.sample_scaled(omega, &mut self.rng));
                }
                Some(d)
            }
            None => None,
        };
        let id = self.next_id;
        self.next_id += 1;
        Ok(PreparedRequest {
            id,
            part: self.part.clone(),
            cm,
            t_max,
            delays,
            work,
            score: score_ref,
            cache_hit,
        })
    }
}
