//! The [`Session`]: one validated client-side plan — partitioning, code,
//! classes, worker count, latency/deadline discipline — bound to one
//! [`Backend`], owning the encoded-block cache and the request-id space.
//!
//! A session is built once ([`Session::builder`]) and then serves a
//! stream of [`Request`]s. Preparation (split, classify, packet draw,
//! `W_A` materialization) happens on the session so that *every*
//! backend — in-process, pooled, networked — reuses cached `A`-side
//! encodings across a repeated-`A` stream; backends only execute and
//! decode.

use std::sync::Arc;
use std::time::Duration;

use crate::analysis::UepStrategy;
use crate::cluster::{CacheKey, CacheStats, EncodedBlockCache, JobTiming};
use crate::coding::{CodeKind, CodeSpec, Packet, UnknownSpace, WindowPolynomial};
use crate::coordinator::{EncodedA, Outcome, RatelessPlan};
use crate::latency::LatencyModel;
use crate::linalg::Matrix;
use crate::partition::{ClassMap, Partitioning};
use crate::rng::Pcg64;

use super::adapt::{class_sigma2_from_norms, ReplanEvent, ReplanPolicy, Replanner};
use super::backend::{Backend, Maintenance, PollState};
use super::error::{ApiResult, UepmmError};
use super::progress::Progress;

/// One multiplication request in a session's stream. `a_id` is the
/// caller's stable identity for `A` (e.g. "layer-3 weights"): requests
/// sharing an `a_id` share cached encodings.
#[derive(Clone, Debug)]
pub struct Request {
    pub a_id: u64,
    pub a: Matrix,
    pub b: Matrix,
    /// Per-request deadline override (defaults to the session deadline).
    pub t_max: Option<f64>,
    /// Per-request scoring override (defaults to the session setting).
    pub score: Option<bool>,
    /// Explicit virtual completion times, one per coded job — overrides
    /// sampling from the session's latency model. This is how scenario
    /// experiments inject *actual* (possibly drifting, heterogeneous)
    /// straggle while the session plans under its assumed/fitted model.
    /// Under a rateless code the entries are per-*stream* pacing bases:
    /// stream `s` completes its `k`-th packet at `(k+1)·delays[s]`.
    pub delays: Option<Vec<f64>>,
    /// Rateless codes only: explicit per-stream cumulative packet
    /// completion schedules (`schedules[s][k]` = virtual time stream `s`
    /// finishes its `k`-th packet; non-decreasing per stream). Overrides
    /// both `delays`-based pacing and latency-model sampling — this is
    /// how experiments inject *drifting* per-packet straggle that a
    /// single base delay cannot express.
    pub schedules: Option<Vec<Vec<f64>>>,
}

impl Request {
    pub fn new(a_id: u64, a: Matrix, b: Matrix) -> Request {
        Request { a_id, a, b, t_max: None, score: None, delays: None, schedules: None }
    }

    /// Override the session deadline for this request.
    pub fn deadline(mut self, t_max: f64) -> Request {
        self.t_max = Some(t_max);
        self
    }

    /// Override the session's scoring setting for this request.
    pub fn scored(mut self, score: bool) -> Request {
        self.score = Some(score);
        self
    }

    /// Inject explicit virtual completion times (one per coded job)
    /// instead of sampling from the session's latency model.
    pub fn delays(mut self, delays: Vec<f64>) -> Request {
        self.delays = Some(delays);
        self
    }

    /// Inject explicit per-stream packet completion schedules (rateless
    /// codes only; see [`Request::schedules`]).
    pub fn schedules(mut self, schedules: Vec<Vec<f64>>) -> Request {
        self.schedules = Some(schedules);
        self
    }
}

/// Handle to a submitted request; redeem it with [`Session::poll`] /
/// [`Session::wait`] or abandon it with [`Session::cancel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RequestHandle {
    pub id: u64,
}

/// The unified result of one served request, across every backend.
///
/// This supersedes the per-path result shapes (`Outcome` alone from
/// `Coordinator::run`, the threaded service's `ServiceOutcome`,
/// `ClusterOutcome` from `ClusterServer`): the decode [`Outcome`] plus
/// the accounting every path shares, plus the anytime [`Progress`]
/// stream.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Decode result: received/recovered counts, `Ĉ`, loss (NaN when
    /// the request was not scored).
    pub outcome: Outcome,
    /// Results that were computed but missed the deadline.
    pub late: usize,
    /// Jobs handed to the execution path.
    pub dispatched: usize,
    /// Re-dispatches of jobs stranded on workers that died mid-request
    /// (cluster-backed paths; always 0 in-process). A failure costs
    /// latency, not work: retried slots still land as `received`.
    pub retries: usize,
    /// Result frames naming a slot outside the request's job set (a
    /// broken worker; the sender is evicted and its work re-dispatched)
    /// plus checksum-damaged frames (the sender keeps its slots; the
    /// affected work requeues).
    pub corrupt: usize,
    /// Arriving results that failed Freivalds verification (tampered or
    /// miscomputed payloads); the slot requeues and the sender earns a
    /// strike. Networked backends only — always 0 in-process.
    pub verify_failures: usize,
    /// Workers quarantined (struck out on verification) as of this
    /// request's completion.
    pub quarantined: usize,
    /// Wall time the request took end to end.
    pub wall: Duration,
    /// `Some(hit)` when served through the session's encoded-block
    /// cache (`None` in selective-compute mode, which skips `W_A`, and
    /// for rateless requests, which derive packets instead of caching
    /// encodings).
    pub cache_hit: Option<bool>,
    /// Rateless requests: packets absorbed into the decode, by the id of
    /// the worker (or virtual stream) that delivered them — one entry
    /// per dispatched stream. Empty for fixed-rate requests.
    pub worker_packets: Vec<(u64, usize)>,
    /// Rateless partial credit: the minimum, over streams that had any
    /// packets scheduled, of packets credited to the stream's owner.
    /// `> 0` means even the slowest worker contributed decoded work.
    /// Always 0 for fixed-rate requests.
    pub partial_packets: usize,
    /// Name of the backend that served the request.
    pub backend: &'static str,
    /// Per-job round-trip telemetry (one record per classified result,
    /// in-deadline or late, in absorption order) — the raw material of
    /// the latency estimators behind [`super::SessionBuilder::adaptive`].
    pub timings: Vec<JobTiming>,
    /// The recorded refinement stream (one event per absorbed
    /// in-deadline result).
    pub progress: Progress,
}

impl RunReport {
    /// Dispatched jobs whose results were never seen: slots written off
    /// after exhausting their re-dispatch budget (every holder died),
    /// and post-grace stragglers in wall-deadline mode.
    pub fn missing(&self) -> usize {
        self.dispatched - self.outcome.received - self.late
    }
}

/// Scoring reference for one request: the exact product and its Gram
/// matrix, computed locally. Evaluation only — production streams skip
/// it (`score = false`) because the local `A·B` dwarfs dispatch+decode.
#[derive(Clone, Debug)]
pub struct ScoreRef {
    /// The exact product `A·B`.
    pub c_true: Matrix,
    /// Gram matrix `G_ij = ⟨C_i, C_j⟩_F` of the true sub-products
    /// (drives the running progress loss).
    pub gram: Matrix,
    /// `‖C‖²_F` read off the Gram matrix.
    pub energy: f64,
}

/// The work a backend receives for one request, fully prepared by the
/// session.
#[derive(Clone, Debug)]
pub enum PreparedWork {
    /// Materialized per-worker factor pairs: `wa` handles from the
    /// (possibly cached) [`EncodedA`], plus this request's freshly
    /// bound right factors. Honest compute: workers multiply exactly
    /// these.
    Encoded { enc: Arc<EncodedA>, wb: Vec<Matrix> },
    /// Coefficient-only decode over the raw block split; recovered
    /// sub-products are then computed exactly and directly. The
    /// training fast path (`W_A` is never materialized) — in-process
    /// backends only.
    Blocks {
        space: UnknownSpace,
        packets: Vec<Packet>,
        a_blocks: Vec<Matrix>,
        b_blocks: Vec<Matrix>,
    },
    /// Rateless stream: the deterministic [`RatelessPlan`] from which
    /// any `(stream, seq)` packet — and its honest payload — derives,
    /// plus the per-stream cumulative completion schedules that pace it
    /// in virtual time (ignored by wall-clock backends, where pacing is
    /// a property of the workers).
    Rateless { plan: Arc<RatelessPlan>, schedules: Vec<Vec<f64>> },
}

/// One fully prepared request as handed to a [`Backend`].
#[derive(Clone, Debug)]
pub struct PreparedRequest {
    pub id: u64,
    pub part: Partitioning,
    pub cm: ClassMap,
    /// Deadline in virtual time units.
    pub t_max: f64,
    /// Pre-sampled virtual completion times, one per packet (absent
    /// when the session has no latency model: timing is then up to the
    /// workers/transport).
    pub delays: Option<Vec<f64>>,
    pub work: PreparedWork,
    pub score: Option<ScoreRef>,
    /// Whether the `A`-side came out of the session cache.
    pub cache_hit: Option<bool>,
    /// Replan decisions taken while preparing this request (adaptive
    /// sessions; surfaced in the request's [`Progress`] stream).
    pub replans: Vec<ReplanEvent>,
}

impl PreparedRequest {
    /// Coded jobs (= packets) in this request. For a rateless request
    /// this is the *scheduled* packet count — the decode typically stops
    /// well short of it.
    pub fn jobs(&self) -> usize {
        match &self.work {
            PreparedWork::Encoded { enc, .. } => enc.packets.len(),
            PreparedWork::Blocks { packets, .. } => packets.len(),
            PreparedWork::Rateless { schedules, .. } => {
                schedules.iter().map(|s| s.len()).sum()
            }
        }
    }
}

/// How sub-products are classified into importance levels.
#[derive(Clone, Debug)]
pub enum Classes {
    /// Estimate per request from the operands' block norms (`S` levels).
    Auto(usize),
    /// Pinned assignment (synthetic experiments, coherent cache keys).
    Pinned(ClassMap),
}

/// The paper's Ω capacity scaling (Remark 1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OmegaMode {
    /// `Ω = #sub-products / workers`, recomputed from the session plan.
    Auto,
    Fixed(f64),
}

/// How worker payloads are produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compute {
    /// Materialize `W_A`/`W_B` and multiply them — what real workers do.
    Honest,
    /// Coefficient-only decode, then compute only the recovered
    /// sub-products exactly (the training fast path; in-process only).
    Selective,
}

/// Builder for [`Session`]; validates the full plan up front so a
/// misconfigured stream fails at [`SessionBuilder::build`], not on
/// request `N`.
pub struct SessionBuilder {
    part: Option<Partitioning>,
    spec: Option<CodeSpec>,
    classes: Classes,
    workers: Option<usize>,
    latency: Option<LatencyModel>,
    omega: OmegaMode,
    deadline: Option<f64>,
    score: bool,
    compute: Compute,
    cache_capacity: usize,
    seed: u64,
    tenant: u64,
    adaptive: Option<ReplanPolicy>,
    backend: Option<Box<dyn Backend>>,
}

impl SessionBuilder {
    fn new() -> SessionBuilder {
        SessionBuilder {
            part: None,
            spec: None,
            classes: Classes::Auto(3),
            workers: None,
            latency: None,
            omega: OmegaMode::Auto,
            deadline: None,
            score: false,
            compute: Compute::Honest,
            cache_capacity: 16,
            seed: 0,
            tenant: 0,
            adaptive: None,
            backend: None,
        }
    }

    /// Block partitioning of the operands (paper §II).
    pub fn partitioning(mut self, part: Partitioning) -> Self {
        self.part = Some(part);
        self
    }

    /// The fully specified code (kind + encoding style).
    pub fn code(mut self, spec: CodeSpec) -> Self {
        self.spec = Some(spec);
        self
    }

    /// Pin the importance-class assignment.
    pub fn classes(mut self, cm: ClassMap) -> Self {
        self.classes = Classes::Pinned(cm);
        self
    }

    /// Classify per request from block norms into `s_levels` levels.
    ///
    /// Note: an auto class map depends on each request's `B`, so the
    /// encoded-block cache cannot apply — repeated-`A` streams that
    /// want cache hits must pin their classes with
    /// [`Self::classes`].
    pub fn auto_classes(mut self, s_levels: usize) -> Self {
        self.classes = Classes::Auto(s_levels);
        self
    }

    /// Coded packets (= jobs) per request.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers);
        self
    }

    /// Straggle model used to pre-sample virtual completion times.
    pub fn latency(mut self, model: LatencyModel) -> Self {
        self.latency = Some(model);
        self
    }

    /// Ω capacity scaling mode (default: auto, per Remark 1).
    pub fn omega(mut self, omega: OmegaMode) -> Self {
        self.omega = omega;
        self
    }

    /// Default per-request deadline `T_max` (virtual time units).
    pub fn deadline(mut self, t_max: f64) -> Self {
        self.deadline = Some(t_max);
        self
    }

    /// Score every request against the locally computed exact product
    /// (evaluation streams; default off).
    pub fn score(mut self, score: bool) -> Self {
        self.score = score;
        self
    }

    /// Payload production mode (default honest).
    pub fn compute(mut self, compute: Compute) -> Self {
        self.compute = compute;
        self
    }

    /// Encoded-block cache capacity in entries (0 disables caching).
    pub fn cache_capacity(mut self, entries: usize) -> Self {
        self.cache_capacity = entries;
        self
    }

    /// Seed of the session RNG (packet draws + delay sampling).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Tenant id namespacing this session's caller-assigned matrix ids
    /// (default 0). Matrix ids are only unique *within* a tenant; two
    /// sessions sharing one encoded-block cache namespace (e.g. on the
    /// multi-tenant serve plane) must set distinct tenants or risk
    /// cross-tenant cache collisions.
    pub fn tenant(mut self, tenant: u64) -> Self {
        self.tenant = tenant;
        self
    }

    /// Opt into the straggle-adaptive planning loop: the session folds
    /// every report's per-job timings into a latency estimator and,
    /// on the policy's cadence, re-runs the window-polynomial optimizer
    /// against the fitted model — swapping the re-optimized Γ into the
    /// code spec between requests. Requires a NOW/EW UEP code (only they
    /// carry a window polynomial). Each decision is surfaced as a
    /// [`ReplanEvent`] in the next request's [`Progress`] stream; the
    /// encode cache is purged only when re-banding actually changes the
    /// class map (a Γ swap re-keys cache entries on its own).
    pub fn adaptive(mut self, policy: ReplanPolicy) -> Self {
        self.adaptive = Some(policy);
        self
    }

    /// The execution backend serving this session.
    pub fn backend(mut self, backend: impl Backend + 'static) -> Self {
        self.backend = Some(Box::new(backend));
        self
    }

    /// Validate the full plan and assemble the session.
    pub fn build(self) -> ApiResult<Session> {
        let part = self
            .part
            .ok_or_else(|| UepmmError::Config("no partitioning set".to_string()))?;
        let spec = self
            .spec
            .ok_or_else(|| UepmmError::Config("no code spec set".to_string()))?;
        let backend = self
            .backend
            .ok_or_else(|| UepmmError::Config("no backend set".to_string()))?;
        let workers = self
            .workers
            .ok_or_else(|| UepmmError::Config("no worker count set".to_string()))?;
        if workers == 0 {
            return Err(UepmmError::Config("need at least one worker".to_string()));
        }
        let deadline = self
            .deadline
            .ok_or_else(|| UepmmError::Config("no deadline set".to_string()))?;
        validate_deadline(deadline)?;
        match &self.classes {
            Classes::Auto(s) if *s == 0 => {
                return Err(UepmmError::Config(
                    "need at least one importance level".to_string(),
                ))
            }
            Classes::Pinned(cm) if cm.class_of.len() != part.num_products() => {
                return Err(UepmmError::Config(format!(
                    "class map covers {} sub-products, partitioning has {}",
                    cm.class_of.len(),
                    part.num_products()
                )))
            }
            _ => {}
        }
        let caps = backend.capabilities();
        if caps.needs_injected_delays && self.latency.is_none() {
            return Err(UepmmError::Config(format!(
                "backend '{}' replays pre-sampled virtual delays; set a latency model",
                backend.name()
            )));
        }
        if self.compute == Compute::Selective && !caps.selective_compute {
            return Err(UepmmError::Config(format!(
                "backend '{}' cannot run selective (coefficient-only) compute",
                backend.name()
            )));
        }
        let adaptive = match self.adaptive {
            None => None,
            Some(policy) => {
                let strategy = match &spec.kind {
                    CodeKind::NowUep(_) => UepStrategy::Now,
                    CodeKind::EwUep(_) => UepStrategy::Ew,
                    other => {
                        return Err(UepmmError::Config(format!(
                            "adaptive replanning optimizes a window polynomial; \
                             code '{}' has none",
                            other.name()
                        )))
                    }
                };
                let omega = match self.omega {
                    OmegaMode::Auto => {
                        crate::latency::omega(part.num_products(), workers)
                    }
                    OmegaMode::Fixed(w) => w,
                };
                Some(AdaptiveState {
                    replanner: Replanner::new(policy, strategy, omega),
                    pending: Vec::new(),
                })
            }
        };
        Ok(Session {
            part,
            spec,
            classes: self.classes,
            workers,
            latency: self.latency,
            omega: self.omega,
            deadline,
            score: self.score,
            compute: self.compute,
            rng: Pcg64::seed_from(self.seed),
            cache: EncodedBlockCache::new(self.cache_capacity),
            tenant: self.tenant,
            adaptive,
            backend,
            next_id: 1,
        })
    }
}

fn validate_deadline(t_max: f64) -> ApiResult<()> {
    if !t_max.is_finite() || t_max < 0.0 {
        return Err(UepmmError::Deadline(format!(
            "T_max must be finite and non-negative, got {t_max}"
        )));
    }
    Ok(())
}

/// Session-side state of the adaptive planning loop: the [`Replanner`]
/// plus the decisions not yet surfaced through a request's progress
/// stream.
struct AdaptiveState {
    replanner: Replanner,
    pending: Vec<ReplanEvent>,
}

/// One validated client plan bound to one backend. See module docs.
pub struct Session {
    part: Partitioning,
    spec: CodeSpec,
    classes: Classes,
    workers: usize,
    latency: Option<LatencyModel>,
    omega: OmegaMode,
    deadline: f64,
    score: bool,
    compute: Compute,
    rng: Pcg64,
    cache: EncodedBlockCache,
    tenant: u64,
    adaptive: Option<AdaptiveState>,
    backend: Box<dyn Backend>,
    next_id: u64,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    pub fn partitioning(&self) -> &Partitioning {
        &self.part
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The effective Ω capacity scaling.
    pub fn omega_value(&self) -> f64 {
        match self.omega {
            OmegaMode::Auto => {
                crate::latency::omega(self.part.num_products(), self.workers)
            }
            OmegaMode::Fixed(w) => w,
        }
    }

    /// Hit/miss/eviction counters of the session's encoded-block cache.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// The window polynomial currently in force (UEP codes only) — under
    /// [`SessionBuilder::adaptive`] this is the latest re-optimized Γ.
    pub fn current_gamma(&self) -> Option<&WindowPolynomial> {
        match &self.spec.kind {
            CodeKind::NowUep(g) | CodeKind::EwUep(g) => Some(g),
            _ => None,
        }
    }

    /// The latency model currently fitted from observed timings
    /// (adaptive sessions with enough samples; `None` otherwise).
    pub fn fitted_latency(&self) -> Option<LatencyModel> {
        self.adaptive.as_ref().and_then(|a| a.replanner.fitted())
    }

    /// Per-worker straggle scale offsets observed so far (`(worker id,
    /// scale)`, 1.0 = fleet average), sorted by id. Operator telemetry:
    /// the Γ objective itself uses the fleet-wide fit (Theorems 2/3
    /// model i.i.d. workers), while the cluster's dispatch already
    /// sheds load from high-EWMA workers server-side.
    pub fn worker_scales(&self) -> Vec<(u64, f64)> {
        self.adaptive
            .as_ref()
            .map_or_else(Vec::new, |a| a.replanner.fleet().scales())
    }

    /// Replans performed so far (0 for non-adaptive sessions).
    pub fn replan_count(&self) -> usize {
        self.adaptive.as_ref().map_or(0, |a| a.replanner.replans())
    }

    /// Fold one finished report's telemetry into the adaptive loop.
    fn note_report(&mut self, report: &RunReport) {
        if let Some(adapt) = self.adaptive.as_mut() {
            for t in &report.timings {
                adapt.replanner.observe_timing(t.worker, t.delay);
            }
            adapt.replanner.note_completed();
        }
    }

    /// Prepare and enqueue one request; returns immediately with a
    /// handle. Backends pipeline queued requests in submission order.
    pub fn submit(&mut self, req: Request) -> ApiResult<RequestHandle> {
        let mut prep = self.prepare(req)?;
        // pending replan decisions ride on the first request the backend
        // actually accepts; a failed prepare/submit leaves them pending
        // so no decision ever goes unreported
        if let Some(adapt) = self.adaptive.as_mut() {
            prep.replans = std::mem::take(&mut adapt.pending);
        }
        let id = prep.id;
        let replans = prep.replans.clone();
        if let Err(e) = self.backend.submit(prep) {
            if let Some(adapt) = self.adaptive.as_mut() {
                adapt.pending = replans;
            }
            return Err(e);
        }
        Ok(RequestHandle { id })
    }

    /// Batched submission: prepare the whole stream (so a repeated-`A`
    /// stream pays one encode and `N−1` cache hits up front) and hand
    /// every request to the backend before any result is awaited.
    pub fn submit_batch(
        &mut self,
        reqs: impl IntoIterator<Item = Request>,
    ) -> ApiResult<Vec<RequestHandle>> {
        let mut handles = Vec::new();
        for req in reqs {
            handles.push(self.submit(req)?);
        }
        Ok(handles)
    }

    /// One poll step. `Pending` carries the refinement events recorded
    /// since the last poll (streaming backends absorb one arrival per
    /// poll); `Ready` consumes the handle and yields the full report.
    pub fn poll(&mut self, h: RequestHandle) -> ApiResult<PollState> {
        let state = self.backend.poll(h.id)?;
        if let PollState::Ready(report) = &state {
            self.note_report(report);
        }
        Ok(state)
    }

    /// Drive the backend until the request completes.
    pub fn wait(&mut self, h: RequestHandle) -> ApiResult<RunReport> {
        loop {
            match self.poll(h)? {
                PollState::Ready(report) => return Ok(report),
                PollState::Pending(_) => {}
            }
        }
    }

    /// `submit` + `wait` in one call.
    pub fn run(&mut self, req: Request) -> ApiResult<RunReport> {
        let h = self.submit(req)?;
        self.wait(h)
    }

    /// Cancel a request. An in-flight streaming request finalizes with
    /// whatever it decoded so far (the anytime contract) — `Some`
    /// carries that partial report; `None` means the request was
    /// dropped before any work happened (or the handle was unknown).
    pub fn cancel(&mut self, h: RequestHandle) -> ApiResult<Option<RunReport>> {
        let report = self.backend.cancel(h.id)?;
        if let Some(report) = &report {
            self.note_report(report);
        }
        Ok(report)
    }

    /// Backend upkeep between requests: heartbeat/evict dead workers on
    /// networked backends, a no-op elsewhere. Adaptive sessions also
    /// absorb the registry's per-worker straggle snapshot here.
    pub fn maintain(&mut self) -> ApiResult<Maintenance> {
        let mut m = self.backend.maintain()?;
        if let Some(adapt) = self.adaptive.as_mut() {
            adapt.replanner.observe_straggle(&m.straggle);
        }
        // fold in the session-owned encode cache's per-tenant rows
        // (remote backends may already report plane-side tenants; the
        // session's own rows are appended after them)
        m.cache_tenants.extend(self.cache.tenant_stats());
        Ok(m)
    }

    /// Orderly teardown of the backend (graceful worker shutdown on
    /// cluster backends).
    pub fn shutdown(mut self) -> ApiResult<()> {
        self.backend.shutdown()
    }

    // ---------------------------------------------------------- prepare

    fn prepare(&mut self, req: Request) -> ApiResult<PreparedRequest> {
        if req.a.shape() != self.part.a_shape() {
            return Err(UepmmError::Config(format!(
                "A is {:?}, partitioning expects {:?}",
                req.a.shape(),
                self.part.a_shape()
            )));
        }
        if req.b.shape() != self.part.b_shape() {
            return Err(UepmmError::Config(format!(
                "B is {:?}, partitioning expects {:?}",
                req.b.shape(),
                self.part.b_shape()
            )));
        }
        let t_max = req.t_max.unwrap_or(self.deadline);
        validate_deadline(t_max)?;
        // a due adaptive step needs per-block norms anyway: compute them
        // once and share them between the auto classification and the
        // replan (σ² estimate + optional re-banding)
        let replan_due = self.adaptive.as_ref().map_or(false, |a| {
            a.replanner.due() && a.replanner.fitted().is_some()
        });
        let shared_norms: Option<(Vec<f64>, Vec<f64>)> = replan_due.then(|| {
            (
                self.part.split_a(&req.a).iter().map(|m| m.frob_sq()).collect(),
                self.part.split_b(&req.b).iter().map(|m| m.frob_sq()).collect(),
            )
        });
        let mut cm = match (&self.classes, &shared_norms) {
            (Classes::Pinned(cm), _) => cm.clone(),
            (Classes::Auto(s), Some((a_norms, b_norms))) => {
                ClassMap::from_norms(&self.part, a_norms, b_norms, *s)
            }
            (Classes::Auto(s), None) => {
                ClassMap::from_matrices(&self.part, &req.a, &req.b, *s)
            }
        };
        self.maybe_replan(&req, &mut cm, shared_norms);
        if replan_due {
            // re-assignment on the replanner cadence: each time the
            // cadence fires with a fitted model, the fresh per-worker
            // scale offsets go down to the backend, where
            // `ClusterConfig::hetero_assign` plans the next request's
            // unequal slot→worker map from them (a no-op elsewhere)
            let scales = self.worker_scales();
            if !scales.is_empty() {
                self.backend.apply_worker_scales(&scales)?;
            }
        }
        let score = req.score.unwrap_or(self.score);
        let score_ref = if score {
            // one pass over the sub-products serves both references: the
            // Gram matrix for the running progress loss, and the exact
            // product (assembled from the same blocks, both paradigms)
            // for the final honest score — no second full matmul
            let products = self.part.true_products(&req.a, &req.b);
            let gram = self.part.gram(&products);
            let energy = self
                .part
                .loss_from_gram(&gram, &vec![false; self.part.num_products()]);
            let c_true = self
                .part
                .assemble(&products.into_iter().map(Some).collect::<Vec<_>>());
            Some(ScoreRef { c_true, gram, energy })
        } else {
            None
        };
        // a rateless code has no fixed packet set to materialize or
        // cache: the prepared work is the deterministic plan (coder +
        // blocks) plus the virtual pacing of each worker's stream
        let rateless_spec = match &self.spec.kind {
            CodeKind::Rateless(r) => Some(r.clone()),
            _ => None,
        };
        if let Some(rspec) = rateless_spec {
            if self.compute == Compute::Selective {
                return Err(UepmmError::Config(
                    "selective compute is fixed-rate only; rateless streams \
                     already decode packet by packet"
                        .to_string(),
                ));
            }
            let plan = RatelessPlan::build_with_classes(
                &self.part,
                rspec,
                cm.clone(),
                &req.a,
                &req.b,
            )
            .map_err(|e| UepmmError::Encode(format!("{e:#}")))?;
            let schedules =
                self.rateless_schedules(&req, t_max, plan.num_unknowns())?;
            let id = self.next_id;
            self.next_id += 1;
            return Ok(PreparedRequest {
                id,
                part: self.part.clone(),
                cm,
                t_max,
                delays: None,
                work: PreparedWork::Rateless { plan: Arc::new(plan), schedules },
                score: score_ref,
                cache_hit: None,
                replans: Vec::new(),
            });
        }
        if req.schedules.is_some() {
            return Err(UepmmError::Config(
                "per-stream schedules apply to rateless codes only; \
                 fixed-rate requests inject per-job delays"
                    .to_string(),
            ));
        }
        let (work, cache_hit) = match self.compute {
            Compute::Honest => {
                // the cache is only coherent under pinned classes: an
                // auto class map depends on each request's B, so its
                // entries could never be shared across a stream — build
                // the encoding directly (and retain nothing) instead of
                // silently filling the cache with dead entries
                let cacheable = matches!(self.classes, Classes::Pinned(_));
                let (enc, hit) = if cacheable {
                    let key = CacheKey::new(
                        self.tenant,
                        req.a_id,
                        &self.part,
                        &self.spec,
                        &cm,
                        self.workers,
                    );
                    let part = &self.part;
                    let spec = &self.spec;
                    let workers = self.workers;
                    let rng = &mut self.rng;
                    let (enc, hit) = self
                        .cache
                        .get_or_insert_with(key, || {
                            EncodedA::encode(
                                part,
                                spec.clone(),
                                &cm,
                                workers,
                                &req.a,
                                rng,
                            )
                        })
                        .map_err(|e| UepmmError::Encode(format!("{e:#}")))?;
                    (enc, Some(hit))
                } else {
                    let enc = EncodedA::encode(
                        &self.part,
                        self.spec.clone(),
                        &cm,
                        self.workers,
                        &req.a,
                        &mut self.rng,
                    )
                    .map_err(|e| UepmmError::Encode(format!("{e:#}")))?;
                    (Arc::new(enc), None)
                };
                let b_blocks = self.part.split_b(&req.b);
                let wb: Vec<Matrix> =
                    (0..enc.workers()).map(|w| enc.job_b(&b_blocks, w)).collect();
                (PreparedWork::Encoded { enc, wb }, hit)
            }
            Compute::Selective => {
                // no W_A materialization and no caching: the training
                // shape changes A every call, so cached encodings would
                // never be coherent anyway
                let a_blocks = self.part.split_a(&req.a);
                let b_blocks = self.part.split_b(&req.b);
                let packets = self.spec.generate_packets(
                    &self.part,
                    &cm,
                    self.workers,
                    &mut self.rng,
                );
                let space = UnknownSpace::for_code(&self.part, self.spec.style);
                (
                    PreparedWork::Blocks { space, packets, a_blocks, b_blocks },
                    None,
                )
            }
        };
        let omega = self.omega_value();
        // explicit per-request delays short-circuit model sampling (and
        // consume no session randomness — an injected stream and a
        // sampled stream are different RNG histories by design)
        let delays = match &req.delays {
            Some(d) => {
                if d.len() != self.workers {
                    return Err(UepmmError::Config(format!(
                        "{} injected delays for {} coded jobs",
                        d.len(),
                        self.workers
                    )));
                }
                Some(d.clone())
            }
            None => match self.latency.clone() {
                Some(model) => {
                    let mut d = Vec::with_capacity(self.workers);
                    for _ in 0..self.workers {
                        d.push(model.sample_scaled(omega, &mut self.rng));
                    }
                    Some(d)
                }
                None => None,
            },
        };
        let id = self.next_id;
        self.next_id += 1;
        Ok(PreparedRequest {
            id,
            part: self.part.clone(),
            cm,
            t_max,
            delays,
            work,
            score: score_ref,
            cache_hit,
            // pending replan decisions are attached by `submit`, once
            // the backend is committed to serving this request
            replans: Vec::new(),
        })
    }

    /// Build the per-stream packet pacing of one rateless request:
    /// explicit injected schedules win, then `delays`-based linear
    /// pacing (stream `s` finishes packet `k` at `(k+1)·delays[s]`),
    /// then pacing bases sampled from the session's latency model.
    /// Derived schedules stop at the deadline and are capped at
    /// `2·K + 16` packets per stream — enough for any single stream to
    /// carry the whole decode (robust-soliton overhead is `o(K)`).
    fn rateless_schedules(
        &mut self,
        req: &Request,
        t_max: f64,
        unknowns: usize,
    ) -> ApiResult<Vec<Vec<f64>>> {
        if let Some(scheds) = &req.schedules {
            if scheds.len() != self.workers {
                return Err(UepmmError::Config(format!(
                    "{} injected schedules for {} worker streams",
                    scheds.len(),
                    self.workers
                )));
            }
            for (s, sched) in scheds.iter().enumerate() {
                for (k, &t) in sched.iter().enumerate() {
                    let ok = t.is_finite()
                        && t >= 0.0
                        && (k == 0 || t >= sched[k - 1]);
                    if !ok {
                        return Err(UepmmError::Config(format!(
                            "schedule of stream {s} must be finite, \
                             non-negative, and non-decreasing"
                        )));
                    }
                }
            }
            return Ok(scheds.clone());
        }
        let omega = self.omega_value();
        let bases: Vec<f64> = match &req.delays {
            Some(d) => {
                if d.len() != self.workers {
                    return Err(UepmmError::Config(format!(
                        "{} injected pacing bases for {} worker streams",
                        d.len(),
                        self.workers
                    )));
                }
                if d.iter().any(|b| !b.is_finite() || *b <= 0.0) {
                    return Err(UepmmError::Config(
                        "rateless pacing bases must be finite and positive"
                            .to_string(),
                    ));
                }
                d.clone()
            }
            None => match self.latency.clone() {
                Some(model) => (0..self.workers)
                    .map(|_| model.sample_scaled(omega, &mut self.rng))
                    .collect(),
                None => {
                    return Err(UepmmError::Config(
                        "rateless pacing needs injected delays/schedules or \
                         a session latency model"
                            .to_string(),
                    ))
                }
            },
        };
        let cap = 2 * unknowns + 16;
        Ok(bases
            .iter()
            .map(|&b| {
                let mut sched = Vec::with_capacity(cap.min(64));
                let mut t = b;
                while t <= t_max && sched.len() < cap {
                    sched.push(t);
                    t += b;
                }
                sched
            })
            .collect())
    }

    /// The adaptive step, run while preparing a request once the
    /// replanner's cadence is due: optionally re-band pinned classes
    /// from this request's actual block norms (purging the encode cache
    /// only when the assignment really changed), then fit the latency
    /// model from observed timings and re-optimize the window
    /// polynomial against it. Decisions are buffered on the adaptive
    /// state; `submit` attaches them to the first request the backend
    /// accepts.
    fn maybe_replan(
        &mut self,
        req: &Request,
        cm: &mut ClassMap,
        shared_norms: Option<(Vec<f64>, Vec<f64>)>,
    ) {
        let omega = self.omega_value();
        let Some(adapt) = self.adaptive.as_mut() else {
            return;
        };
        if adapt.replanner.due() {
            // no fittable model (degenerate samples, or a policy with
            // min_samples below the fit's own floor) ⇒ skip the whole
            // step — leaving the cadence pending for the next prepare —
            // rather than re-banding against a fit that will not come;
            // every surfaced class change thus rides a ReplanEvent
            if adapt.replanner.fitted().is_none() {
                return;
            }
            // one split of each operand serves both the re-banding and
            // the per-class σ² estimate (blocks of a side share a
            // shape); `prepare` hands the norms down when the auto
            // classification already computed them
            let (a_norms, b_norms) = shared_norms.unwrap_or_else(|| {
                (
                    self.part.split_a(&req.a).iter().map(|m| m.frob_sq()).collect(),
                    self.part.split_b(&req.b).iter().map(|m| m.frob_sq()).collect(),
                )
            });
            let mut classes_changed = false;
            if adapt.replanner.policy().reband {
                if let Classes::Pinned(pinned) = &self.classes {
                    let fresh = ClassMap::from_norms(
                        &self.part,
                        &a_norms,
                        &b_norms,
                        pinned.s_levels,
                    );
                    if fresh.class_of != pinned.class_of {
                        // entries keyed under the old class map can
                        // never be hit again; an unchanged map keeps
                        // the cache untouched
                        self.cache.clear();
                        classes_changed = true;
                        *cm = fresh.clone();
                        self.classes = Classes::Pinned(fresh);
                    }
                }
            }
            let gamma_now: Vec<f64> = match &self.spec.kind {
                CodeKind::NowUep(g) | CodeKind::EwUep(g) => {
                    g.resized(cm.n_classes).probs().to_vec()
                }
                _ => unreachable!("adaptive sessions are validated UEP at build"),
            };
            let sigma2 = class_sigma2_from_norms(
                &self.part,
                cm,
                &a_norms,
                &b_norms,
                (req.a.rows() * req.a.cols() / a_norms.len()) as f64,
                (req.b.rows() * req.b.cols() / b_norms.len()) as f64,
            );
            // optimize for the deadline this stream actually runs under:
            // an explicit policy t* wins, then the request's own
            // deadline override, then the session default
            let t_star = adapt
                .replanner
                .policy()
                .t_star
                .unwrap_or_else(|| req.t_max.unwrap_or(self.deadline));
            let samples = adapt.replanner.fleet().observations();
            let after_requests = adapt.replanner.completed();
            if let Some((model, opt)) = adapt.replanner.replan(
                &self.part,
                cm,
                sigma2,
                gamma_now.clone(),
                self.workers,
                omega,
                t_star,
            ) {
                let improved = opt.loss + 1e-12 < opt.initial_loss;
                if improved {
                    // the optimizer's mass transfers can leave an edge
                    // weight a few ulp below zero; clamp rather than
                    // trip WindowPolynomial's non-negativity assert
                    let clamped: Vec<f64> =
                        opt.gamma.iter().map(|g| g.max(0.0)).collect();
                    let wp = WindowPolynomial::new(&clamped);
                    self.spec.kind = match &self.spec.kind {
                        CodeKind::NowUep(_) => CodeKind::NowUep(wp),
                        CodeKind::EwUep(_) => CodeKind::EwUep(wp),
                        _ => unreachable!("validated at build"),
                    };
                }
                adapt.pending.push(ReplanEvent {
                    after_requests,
                    samples,
                    model,
                    gamma_after: if improved {
                        opt.gamma.clone()
                    } else {
                        gamma_now.clone()
                    },
                    gamma_before: gamma_now,
                    predicted_before: opt.initial_loss,
                    predicted_after: if improved { opt.loss } else { opt.initial_loss },
                    classes_changed,
                });
            }
        }
    }
}
