//! The [`Backend`] trait and its three adapters — one per execution
//! path the crate grew historically:
//!
//! * [`InProcessBackend`] — the virtual-time honest path
//!   (`Coordinator::run` semantics): payloads computed through an
//!   [`ExecEngine`] in this thread, arrivals replayed from pre-sampled
//!   delays. The only *streaming* backend: each `poll` absorbs one
//!   arrival, so a caller can consume `Ĉ(t)` anytime and `cancel` keeps
//!   whatever has decoded so far.
//! * [`PooledBackend`] — the in-process thread-pool path: loopback
//!   worker threads behind the cluster wire protocol, deterministic
//!   virtual deadlines.
//! * [`ClusterBackend`] — the networked path: any
//!   [`ClusterServer`] (TCP workers in `Wall` mode, or loopback in
//!   `Virtual` mode) with registry, heartbeat/eviction, and failover.
//!
//! All three consume the same [`PreparedRequest`] built by the
//! [`super::Session`] and produce the same [`RunReport`], which is what
//! makes the backend-equivalence guarantee testable: same seed, same
//! session config ⇒ bit-identical `Outcome` across backends.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cluster::transport::{Connection, TcpConn};
use crate::cluster::wire::{ClientResultMsg, Msg, SubmitMsg, WireError};
use crate::cluster::{
    spawn_loopback_workers, ClusterConfig, ClusterServer, DeadlineMode, DecodeStep,
    JobTiming, LoopbackTransport, WorkerConfig, WorkerInfo, WorkerStats,
};
use crate::coding::DecodeState;
use crate::coordinator::{assemble_outcome, score_outcome, Outcome};
use crate::linalg::{matmul, Matrix};
use crate::partition::Paradigm;
use crate::runtime::{ExecEngine, NativeEngine};

use super::error::{classify_cluster_error, ApiResult, UepmmError};
use super::progress::{Progress, ProgressEvent, ProgressTracker};
use super::session::{PreparedRequest, PreparedWork, RunReport, ScoreRef};

/// What a backend can and cannot do; checked by the session builder so
/// misconfiguration fails up front.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Capabilities {
    /// Same seed ⇒ bit-identical outcome (virtual-time disciplines).
    pub deterministic: bool,
    /// Results cross a process/socket boundary.
    pub networked: bool,
    /// `poll` absorbs one arrival at a time (true anytime streaming);
    /// non-streaming backends complete a request on its first poll.
    pub streaming: bool,
    /// The backend replays pre-sampled virtual delays, so the session
    /// must carry a latency model.
    pub needs_injected_delays: bool,
    /// Supports coefficient-only selective compute
    /// ([`super::Compute::Selective`]).
    pub selective_compute: bool,
}

/// Result of one [`Backend::poll`] step.
#[derive(Debug)]
pub enum PollState {
    /// Not finished; carries refinement events recorded since the last
    /// poll (empty for backends that report everything in the final
    /// [`RunReport::progress`]).
    Pending(Vec<ProgressEvent>),
    /// Finished; the handle is consumed.
    Ready(RunReport),
}

/// Upkeep report from [`Backend::maintain`].
#[derive(Clone, Debug, Default)]
pub struct Maintenance {
    /// Workers evicted by the heartbeat (networked backends).
    pub evicted: Vec<u64>,
    /// Live workers after upkeep, where the notion applies.
    pub live_workers: Option<usize>,
    /// In-flight result frames the heartbeat read while waiting for
    /// acks and routed into worker inboxes instead of being dropped.
    /// The next served request drains them through the normal result
    /// classifier (current-request frames absorb with full accounting;
    /// completed-request frames are discarded only once provably
    /// stale), and the frames credit liveness so a backlogged straggler
    /// is not mis-evicted — a stream interleaved with `maintain()`
    /// calls reports bit-identically to one without.
    pub buffered_results: usize,
    /// Registry snapshot of each worker's EWMA straggle score
    /// (`(worker id, score)`; `None` before a worker's first accepted
    /// result). Networked backends only; the adaptive session feeds
    /// this into its [`crate::latency::FleetEstimator`].
    pub straggle: Vec<(u64, Option<f64>)>,
    /// Registry snapshot of per-worker Freivalds verification strikes
    /// (`(worker id, strikes)`), workers with zero strikes included.
    /// Networked backends only.
    pub verify_failures: Vec<(u64, u32)>,
    /// Workers currently quarantined (evicted for lying and barred from
    /// rejoin until `ClusterServer::reset_quarantine`). Networked
    /// backends only.
    pub quarantined: Vec<u64>,
    /// Per-tenant encoded-block cache accounting, `(tenant, hits,
    /// misses)` sorted by tenant id. Populated by [`super::Session`]
    /// (the cache owner) on top of whatever the backend reports; tenants
    /// that never touched the cache are absent.
    pub cache_tenants: Vec<(u64, u64, u64)>,
}

/// One execution path behind the unified client API.
pub trait Backend {
    /// Stable name for logs and [`RunReport::backend`].
    fn name(&self) -> &'static str;

    fn capabilities(&self) -> Capabilities;

    /// Enqueue one prepared request. Returns immediately; execution is
    /// driven by `poll`.
    fn submit(&mut self, prep: PreparedRequest) -> ApiResult<()>;

    /// Drive execution one step for the given request id.
    fn poll(&mut self, id: u64) -> ApiResult<PollState>;

    /// Cancel a request: `Some(report)` when work had been done (a
    /// streaming backend finalizes its partial decode — the anytime
    /// contract), `None` when the request was dropped unstarted or the
    /// id is unknown.
    fn cancel(&mut self, id: u64) -> ApiResult<Option<RunReport>>;

    /// Between-request upkeep (heartbeat/eviction on networked
    /// backends). Default: no-op.
    fn maintain(&mut self) -> ApiResult<Maintenance> {
        Ok(Maintenance::default())
    }

    /// Push fitted per-worker scale offsets `(registry id, scale)` down
    /// to the execution plane — 1.0 = fleet mean, higher = slower —
    /// where heterogeneity-aware dispatch
    /// ([`crate::cluster::ClusterConfig::hetero_assign`]) plans unequal
    /// work from them. Adaptive sessions push on their `Replanner`
    /// cadence. Default: no-op — backends without a worker fleet (and
    /// remote clients, whose plane keeps its own per-lane estimates)
    /// ignore it.
    fn apply_worker_scales(&mut self, _scales: &[(u64, f64)]) -> ApiResult<()> {
        Ok(())
    }

    /// Install per-worker *injected-delay* multipliers `(registry id,
    /// multiplier)` on the execution plane — the deterministic
    /// heterogeneity-injection hook for evaluation and chaos drills
    /// (see [`crate::cluster::ClusterServer::set_straggle_injection`]).
    /// A worker holding multiplier `m` completes injected-delay jobs as
    /// if `m`× slower. Inert for requests without injected delays.
    /// Default: no-op — backends without a paced worker fleet ignore
    /// it.
    fn inject_straggle(&mut self, _scales: &[(u64, f64)]) -> ApiResult<()> {
        Ok(())
    }

    /// Orderly teardown. Default: no-op.
    fn shutdown(&mut self) -> ApiResult<()> {
        Ok(())
    }
}

// ===================================================== in-process path

/// The virtual-time honest path as a streaming backend. See module docs.
pub struct InProcessBackend<E: ExecEngine = NativeEngine> {
    engine: E,
    active: Vec<InFlight>,
    done: Vec<(u64, RunReport)>,
}

struct InFlight {
    prep: PreparedRequest,
    mode: Mode,
    st: DecodeState,
    received: usize,
    tracker: ProgressTracker,
    start: Instant,
}

/// How one in-flight request replays its virtual arrivals.
enum Mode {
    /// Fixed-rate: worker indices sorted by `(delay, slot)` — the shared
    /// absorb order of every virtual-time path — plus the replay cursor.
    Fixed { order: Vec<usize>, next: usize },
    /// Rateless: the merged in-deadline `(time, stream, seq)` events of
    /// every stream's schedule, sorted by arrival, plus each stream's
    /// in-deadline packet budget and the replay cursor. The stream stops
    /// at decode completion, not at a packet count.
    Rateless { events: Vec<(f64, usize, u32)>, budgets: Vec<usize>, next: usize },
}

impl InProcessBackend<NativeEngine> {
    /// Thread-parallel native engine.
    pub fn native() -> Self {
        InProcessBackend::with_engine(NativeEngine::default())
    }

    /// Single-threaded native engine — use this when comparing against
    /// cluster backends bit for bit (loopback workers compute serially).
    pub fn serial() -> Self {
        InProcessBackend::with_engine(NativeEngine::serial())
    }
}

impl<E: ExecEngine> InProcessBackend<E> {
    pub fn with_engine(engine: E) -> Self {
        InProcessBackend { engine, active: Vec::new(), done: Vec::new() }
    }

    fn finalize(fl: InFlight) -> RunReport {
        let jobs = fl.prep.jobs();
        let prep = fl.prep;
        // accounting and telemetry are mode-shaped: a fixed-rate request
        // knows its late arrivals up front from the delays (arrivals the
        // stream never replayed — an early cancel — are neither received
        // nor late: they show up as missing(), like results a cluster
        // never saw); a rateless request schedules nothing past the
        // deadline, and "dispatched" is what the stream actually
        // generated before the decode completed
        let (late, dispatched, timings, worker_packets, partial_packets) =
            match &fl.mode {
                Mode::Fixed { order, next } => {
                    let replayed = *next;
                    let late = prep
                        .delays
                        .as_ref()
                        .map(|d| d.iter().filter(|&&t| t > prep.t_max).count())
                        .unwrap_or(0);
                    // one record per replayed arrival plus every
                    // knowable-late one, in absorption order; the
                    // virtual "worker" of slot s is s itself
                    let timings: Vec<JobTiming> = match prep.delays.as_ref() {
                        Some(delays) => order
                            .iter()
                            .enumerate()
                            .filter_map(|(idx, &slot)| {
                                let is_late = delays[slot] > prep.t_max;
                                (idx < replayed || is_late).then(|| JobTiming {
                                    slot: slot as u32,
                                    worker: slot as u64,
                                    attempt: 0,
                                    delay: delays[slot],
                                    compute_secs: 0.0,
                                    late: is_late,
                                })
                            })
                            .collect(),
                        None => Vec::new(),
                    };
                    (late, jobs, timings, Vec::new(), 0)
                }
                Mode::Rateless { events, budgets, next } => {
                    let replayed = &events[..*next];
                    let timings: Vec<JobTiming> = replayed
                        .iter()
                        .map(|&(t, s, k)| JobTiming {
                            slot: k,
                            worker: s as u64,
                            attempt: 0,
                            delay: t,
                            compute_secs: 0.0,
                            late: false,
                        })
                        .collect();
                    let mut credit = vec![0usize; budgets.len()];
                    for &(_, s, _) in replayed {
                        credit[s] += 1;
                    }
                    let worker_packets: Vec<(u64, usize)> =
                        credit.iter().enumerate().map(|(s, &c)| (s as u64, c)).collect();
                    let partial = budgets
                        .iter()
                        .zip(&credit)
                        .filter(|(&b, _)| b > 0)
                        .map(|(_, &c)| c)
                        .min()
                        .unwrap_or(0);
                    (0, *next, timings, worker_packets, partial)
                }
            };
        let outcome = match &prep.work {
            PreparedWork::Encoded { .. } | PreparedWork::Rateless { .. } => {
                match &prep.score {
                    Some(s) => score_outcome(
                        &prep.part,
                        &prep.cm,
                        &s.c_true,
                        &fl.st,
                        fl.received,
                    ),
                    None => {
                        assemble_outcome(&prep.part, &prep.cm, &fl.st, fl.received)
                    }
                }
            }
            PreparedWork::Blocks { a_blocks, b_blocks, .. } => {
                // coefficient-only decode: compute exactly the recovered
                // sub-products, directly from the block split
                let mask = fl.st.recovered_mask();
                let values: Vec<Option<Matrix>> = mask
                    .iter()
                    .enumerate()
                    .map(|(u, &rec)| {
                        rec.then(|| {
                            let (ai, bi) = prep.part.factors_of(u);
                            matmul(&a_blocks[ai], &b_blocks[bi])
                        })
                    })
                    .collect();
                let c_hat = prep.part.assemble(&values);
                let mut per_class = vec![0usize; prep.cm.n_classes];
                for (u, &rec) in mask.iter().enumerate() {
                    if rec {
                        per_class[prep.cm.class_of[u]] += 1;
                    }
                }
                let (loss, normalized_loss) = match &prep.score {
                    Some(s) => {
                        let loss = s.c_true.frob_sq_diff(&c_hat);
                        let energy = s.c_true.frob_sq();
                        (loss, if energy > 0.0 { loss / energy } else { 0.0 })
                    }
                    None => (f64::NAN, f64::NAN),
                };
                Outcome {
                    received: fl.received,
                    recovered: mask.iter().filter(|&&b| b).count(),
                    per_class_recovered: per_class,
                    c_hat,
                    loss,
                    normalized_loss,
                }
            }
        };
        RunReport {
            outcome,
            late,
            dispatched,
            // in-process execution has no workers to lose or go rogue
            retries: 0,
            corrupt: 0,
            verify_failures: 0,
            quarantined: 0,
            wall: fl.start.elapsed(),
            cache_hit: prep.cache_hit,
            backend: "in-process",
            timings,
            worker_packets,
            partial_packets,
            progress: fl.tracker.finish(),
        }
    }
}

impl<E: ExecEngine> Backend for InProcessBackend<E> {
    fn name(&self) -> &'static str {
        "in-process"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            deterministic: true,
            networked: false,
            streaming: true,
            needs_injected_delays: true,
            selective_compute: true,
        }
    }

    fn submit(&mut self, prep: PreparedRequest) -> ApiResult<()> {
        let mode = match &prep.work {
            PreparedWork::Rateless { schedules, .. } => {
                // merge every stream's in-deadline completions into one
                // arrival-ordered event list; ties replay in (stream,
                // seq) order, mirroring the cluster server's schedule
                let mut events: Vec<(f64, usize, u32)> = Vec::new();
                let mut budgets = vec![0usize; schedules.len()];
                for (s, sched) in schedules.iter().enumerate() {
                    for (k, &t) in sched.iter().enumerate() {
                        if t <= prep.t_max {
                            events.push((t, s, k as u32));
                            budgets[s] += 1;
                        }
                    }
                }
                events.sort_by(|a, b| {
                    a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2))
                });
                Mode::Rateless { events, budgets, next: 0 }
            }
            _ => {
                let Some(delays) = prep.delays.clone() else {
                    return Err(UepmmError::Config(
                        "in-process backend replays virtual delays; none were \
                         sampled"
                            .to_string(),
                    ));
                };
                if delays.len() != prep.jobs() {
                    return Err(UepmmError::Config(format!(
                        "{} delays for {} jobs",
                        delays.len(),
                        prep.jobs()
                    )));
                }
                let mut order: Vec<usize> = (0..delays.len()).collect();
                order.sort_by(|&x, &y| {
                    delays[x].total_cmp(&delays[y]).then(x.cmp(&y))
                });
                Mode::Fixed { order, next: 0 }
            }
        };
        let space = match &prep.work {
            PreparedWork::Encoded { enc, .. } => enc.space.clone(),
            PreparedWork::Blocks { space, .. } => space.clone(),
            PreparedWork::Rateless { plan, .. } => plan.space.clone(),
        };
        let mut tracker = ProgressTracker::new(&prep.part, prep.score.as_ref());
        tracker.seed_replans(&prep.replans);
        self.active.push(InFlight {
            prep,
            mode,
            st: DecodeState::new(space),
            received: 0,
            tracker,
            start: Instant::now(), // lint:allow(no-wallclock-in-deterministic-paths) RunReport wall telemetry only; results never depend on it
        });
        Ok(())
    }

    fn poll(&mut self, id: u64) -> ApiResult<PollState> {
        if let Some(pos) = self.done.iter().position(|(d, _)| *d == id) {
            return Ok(PollState::Ready(self.done.swap_remove(pos).1));
        }
        let Some(idx) = self.active.iter().position(|fl| fl.prep.id == id) else {
            return Err(UepmmError::Config(format!("unknown request id {id}")));
        };
        let exhausted = {
            let fl = &self.active[idx];
            match &fl.mode {
                Mode::Fixed { order, next } => {
                    let delays =
                        fl.prep.delays.as_ref().expect("validated at submit");
                    *next >= order.len()
                        || delays[order[*next]] > fl.prep.t_max
                }
                // a rateless stream is open-ended: it stops when the
                // decode completes (or the deadline admits no more)
                Mode::Rateless { events, next, .. } => {
                    *next >= events.len() || fl.st.is_complete()
                }
            }
        };
        if exhausted {
            let fl = self.active.swap_remove(idx);
            return Ok(PollState::Ready(Self::finalize(fl)));
        }
        // absorb exactly one arrival: the anytime streaming step
        let fl = &mut self.active[idx];
        let (delay, newly) = match &mut fl.mode {
            Mode::Fixed { order, next } => {
                let w = order[*next];
                *next += 1;
                let delay =
                    fl.prep.delays.as_ref().expect("validated at submit")[w];
                let newly = match &fl.prep.work {
                    PreparedWork::Encoded { enc, wb } => {
                        let payload = self
                            .engine
                            .matmul(&enc.wa[w], &wb[w])
                            .map_err(|e| UepmmError::Compute(format!("{e:#}")))?;
                        fl.st.add_packet(&enc.packets[w], Some(payload))
                    }
                    PreparedWork::Blocks { packets, .. } => {
                        fl.st.add_packet(&packets[w], None)
                    }
                    PreparedWork::Rateless { .. } => {
                        unreachable!("rateless requests run in Mode::Rateless")
                    }
                };
                (delay, newly)
            }
            Mode::Rateless { events, next, .. } => {
                let (t, s, k) = events[*next];
                *next += 1;
                let PreparedWork::Rateless { plan, .. } = &fl.prep.work else {
                    unreachable!("Mode::Rateless implies rateless work")
                };
                let pkt = plan.packet(fl.prep.id, s as u64, k);
                let payload = plan.payload(&pkt);
                (t, fl.st.add_packet(&pkt, Some(payload)))
            }
        };
        fl.received += 1;
        fl.tracker.record(delay, fl.received, fl.st.num_recovered(), &newly, 0);
        Ok(PollState::Pending(fl.tracker.take_new()))
    }

    fn cancel(&mut self, id: u64) -> ApiResult<Option<RunReport>> {
        if let Some(pos) = self.done.iter().position(|(d, _)| *d == id) {
            return Ok(Some(self.done.swap_remove(pos).1));
        }
        if let Some(idx) = self.active.iter().position(|fl| fl.prep.id == id) {
            let fl = self.active.swap_remove(idx);
            let started = fl.received > 0;
            let report = Self::finalize(fl);
            return Ok(if started { Some(report) } else { None });
        }
        Ok(None)
    }
}

// ==================================================== cluster-backed paths

/// Shared driver of the two cluster-backed backends: a [`ClusterServer`]
/// plus the worker thread handles it may own, a FIFO request queue, and
/// finished reports awaiting their `poll`.
struct ClusterCore {
    name: &'static str,
    server: ClusterServer,
    handles: Vec<JoinHandle<anyhow::Result<WorkerStats>>>,
    queue: VecDeque<PreparedRequest>,
    done: Vec<(u64, RunReport)>,
    /// Requests that failed while being served ahead of another poll:
    /// their error is held for their own handle instead of being
    /// misattributed to the request that happened to drive the queue.
    failed: Vec<(u64, UepmmError)>,
}

impl ClusterCore {
    fn new(
        name: &'static str,
        server: ClusterServer,
        handles: Vec<JoinHandle<anyhow::Result<WorkerStats>>>,
    ) -> ClusterCore {
        ClusterCore {
            name,
            server,
            handles,
            queue: VecDeque::new(),
            done: Vec::new(),
            failed: Vec::new(),
        }
    }

    fn submit(&mut self, prep: PreparedRequest) -> ApiResult<()> {
        if matches!(prep.work, PreparedWork::Blocks { .. }) {
            return Err(UepmmError::Config(format!(
                "backend '{}' dispatches materialized jobs; selective compute is \
                 in-process only",
                self.name
            )));
        }
        self.queue.push_back(prep);
        Ok(())
    }

    fn poll(&mut self, id: u64) -> ApiResult<PollState> {
        if let Some(pos) = self.done.iter().position(|(d, _)| *d == id) {
            return Ok(PollState::Ready(self.done.swap_remove(pos).1));
        }
        if let Some(pos) = self.failed.iter().position(|(d, _)| *d == id) {
            return Err(self.failed.swap_remove(pos).1);
        }
        if !self.queue.iter().any(|p| p.id == id) {
            return Err(UepmmError::Config(format!("unknown request id {id}")));
        }
        // serve the queue in submission order up to (and including) the
        // polled request — pipelined FIFO semantics; a failure of an
        // earlier request is parked for its own handle, not reported
        // against the one being polled
        while let Some(prep) = self.queue.pop_front() {
            let pid = prep.id;
            match self.serve(prep) {
                Ok(report) => {
                    if pid == id {
                        return Ok(PollState::Ready(report));
                    }
                    self.done.push((pid, report));
                }
                Err(e) => {
                    if pid == id {
                        return Err(e);
                    }
                    self.failed.push((pid, e));
                }
            }
        }
        unreachable!("request id was in the queue")
    }

    fn cancel(&mut self, id: u64) -> ApiResult<Option<RunReport>> {
        if let Some(pos) = self.done.iter().position(|(d, _)| *d == id) {
            return Ok(Some(self.done.swap_remove(pos).1));
        }
        if let Some(pos) = self.failed.iter().position(|(d, _)| *d == id) {
            self.failed.swap_remove(pos);
            return Ok(None);
        }
        if let Some(pos) = self.queue.iter().position(|p| p.id == id) {
            self.queue.remove(pos);
            return Ok(None);
        }
        Ok(None)
    }

    fn serve(&mut self, prep: PreparedRequest) -> ApiResult<RunReport> {
        let PreparedRequest {
            part, cm, t_max, delays, work, score, cache_hit, replans, ..
        } = prep;
        // pre-validate what the server would reject, so configuration
        // misuse is classified as Config here rather than depending on
        // the wording of the server's internal error messages
        if self.server.config().deadline == DeadlineMode::Wall
            && self.server.config().time_scale <= 0.0
        {
            return Err(UepmmError::Config(
                "Wall deadline mode needs time_scale > 0".to_string(),
            ));
        }
        let (enc, wb) = match work {
            PreparedWork::Encoded { enc, wb } => (enc, wb),
            PreparedWork::Blocks { .. } => unreachable!("rejected at submit"),
            PreparedWork::Rateless { plan, schedules } => {
                let virt = self.server.config().deadline == DeadlineMode::Virtual;
                if virt && schedules.len() != self.server.live_workers() {
                    return Err(UepmmError::Config(format!(
                        "{} stream schedules for {} live workers",
                        schedules.len(),
                        self.server.live_workers()
                    )));
                }
                let mut tracker = ProgressTracker::new(&part, score.as_ref());
                tracker.seed_replans(&replans);
                let served = {
                    let mut obs = |step: DecodeStep| {
                        tracker.record(
                            step.delay,
                            step.received,
                            step.recovered,
                            &step.newly,
                            step.attempt,
                        )
                    };
                    // wall-clock servers pace their own workers; only
                    // virtual-time servers replay the session's schedules
                    self.server
                        .serve_rateless(
                            &plan,
                            t_max,
                            virt.then(|| schedules.as_slice()),
                            Some(&mut obs),
                        )
                        .map_err(classify_cluster_error)?
                };
                let outcome = match &score {
                    Some(s) => score_outcome(
                        &part,
                        &cm,
                        &s.c_true,
                        &served.st,
                        served.received,
                    ),
                    None => assemble_outcome(&part, &cm, &served.st, served.received),
                };
                let quarantined = self.server.quarantined_workers().len();
                return Ok(RunReport {
                    outcome,
                    late: served.late,
                    dispatched: served.dispatched,
                    retries: served.retries,
                    corrupt: served.corrupt,
                    verify_failures: served.verify_failures,
                    quarantined,
                    wall: served.wall,
                    cache_hit,
                    backend: self.name,
                    timings: served.timings,
                    worker_packets: served.worker_packets,
                    partial_packets: served.partial_packets,
                    progress: tracker.finish(),
                });
            }
        };
        if let Some(d) = &delays {
            if d.len() != enc.packets.len() {
                return Err(UepmmError::Config(format!(
                    "{} delays for {} jobs",
                    d.len(),
                    enc.packets.len()
                )));
            }
        }
        // cache hits hand out Arc handles: no W_A deep copy per request
        let jobs: Vec<(Arc<Matrix>, Arc<Matrix>)> =
            enc.wa.iter().cloned().zip(wb.into_iter().map(Arc::new)).collect();
        let mut tracker = ProgressTracker::new(&part, score.as_ref());
        tracker.seed_replans(&replans);
        let served = {
            let mut obs = |step: DecodeStep| {
                tracker.record(
                    step.delay,
                    step.received,
                    step.recovered,
                    &step.newly,
                    step.attempt,
                )
            };
            self.server
                .serve_jobs(
                    &enc.space,
                    &enc.packets,
                    jobs,
                    delays.as_deref(),
                    t_max,
                    Some(&mut obs),
                )
                .map_err(classify_cluster_error)?
        };
        let outcome = match &score {
            Some(s) => score_outcome(&part, &cm, &s.c_true, &served.st, served.received),
            None => assemble_outcome(&part, &cm, &served.st, served.received),
        };
        let quarantined = self.server.quarantined_workers().len();
        Ok(RunReport {
            outcome,
            late: served.late,
            dispatched: served.dispatched,
            retries: served.retries,
            corrupt: served.corrupt,
            verify_failures: served.verify_failures,
            quarantined,
            wall: served.wall,
            cache_hit,
            backend: self.name,
            timings: served.timings,
            worker_packets: served.worker_packets,
            partial_packets: served.partial_packets,
            progress: tracker.finish(),
        })
    }

    fn maintain(&mut self) -> ApiResult<Maintenance> {
        let hb = self.server.heartbeat();
        let info = self.server.worker_info();
        Ok(Maintenance {
            evicted: hb.evicted,
            live_workers: Some(self.server.live_workers()),
            buffered_results: hb.buffered_results,
            straggle: info.iter().map(|w| (w.id, w.straggle)).collect(),
            verify_failures: info.iter().map(|w| (w.id, w.verify_failures)).collect(),
            quarantined: self.server.quarantined_workers(),
            cache_tenants: Vec::new(),
        })
    }

    fn shutdown(&mut self) -> ApiResult<()> {
        self.server.shutdown_graceful(Duration::from_secs(60));
        let mut failure: Option<String> = None;
        for h in self.handles.drain(..) {
            match h.join() {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => failure = Some(format!("worker error: {e:#}")),
                Err(_) => failure = Some("worker thread panicked".to_string()),
            }
        }
        match failure {
            Some(m) => Err(UepmmError::Transport(m)),
            None => Ok(()),
        }
    }
}

fn spawn_loopback_core(
    name: &'static str,
    threads: usize,
    cluster: ClusterConfig,
    worker: WorkerConfig,
    accept_timeout: Duration,
) -> ApiResult<ClusterCore> {
    let threads = threads.max(1);
    let (mut transport, dialer) = LoopbackTransport::new();
    let handles = spawn_loopback_workers(&dialer, threads, &worker);
    drop(dialer);
    let mut server = ClusterServer::new(cluster);
    let joined = server
        .accept_workers(&mut transport, threads, accept_timeout)
        .map_err(|e| UepmmError::Transport(format!("{e:#}")))?;
    if joined != threads {
        return Err(UepmmError::Transport(format!(
            "only {joined}/{threads} loopback workers joined"
        )));
    }
    Ok(ClusterCore::new(name, server, handles))
}

/// The in-process thread-pool path: loopback worker threads, virtual
/// deadlines, deterministic. See module docs.
pub struct PooledBackend {
    core: ClusterCore,
}

impl PooledBackend {
    /// Spawn `threads` loopback worker threads (serial native engine
    /// each — the threads themselves are the parallelism) behind a
    /// virtual-deadline coordinator.
    pub fn spawn(threads: usize) -> ApiResult<PooledBackend> {
        let core = spawn_loopback_core(
            "pooled",
            threads,
            ClusterConfig {
                deadline: DeadlineMode::Virtual,
                time_scale: 0.0,
                // the session owns the encoded-block cache
                cache_capacity: 0,
                ..ClusterConfig::default()
            },
            WorkerConfig { name: "pool".to_string(), ..WorkerConfig::default() },
            Duration::from_secs(30),
        )?;
        Ok(PooledBackend { core })
    }
}

impl Backend for PooledBackend {
    fn name(&self) -> &'static str {
        "pooled"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            deterministic: true,
            networked: false,
            streaming: false,
            needs_injected_delays: true,
            selective_compute: false,
        }
    }

    fn submit(&mut self, prep: PreparedRequest) -> ApiResult<()> {
        self.core.submit(prep)
    }

    fn poll(&mut self, id: u64) -> ApiResult<PollState> {
        self.core.poll(id)
    }

    fn cancel(&mut self, id: u64) -> ApiResult<Option<RunReport>> {
        self.core.cancel(id)
    }

    fn maintain(&mut self) -> ApiResult<Maintenance> {
        self.core.maintain()
    }

    fn apply_worker_scales(&mut self, scales: &[(u64, f64)]) -> ApiResult<()> {
        self.core.server.set_worker_scales(scales);
        Ok(())
    }

    fn inject_straggle(&mut self, scales: &[(u64, f64)]) -> ApiResult<()> {
        self.core.server.set_straggle_injection(scales);
        Ok(())
    }

    fn shutdown(&mut self) -> ApiResult<()> {
        self.core.shutdown()
    }
}

/// The networked path, in one of two modes:
///
/// * **local coordinator** — this process owns a [`ClusterServer`] and
///   drives its registered workers directly ([`ClusterBackend::from_server`],
///   [`ClusterBackend::loopback`]);
/// * **remote client** — this process dials a multi-tenant serve plane
///   ([`crate::cluster::service`]) over wire-v6 client frames and never
///   sees the worker fleet ([`ClusterBackend::connect`]). The session
///   API is unchanged: `submit`/`poll`/`cancel` work identically, the
///   plane streams `ProgressFrame`s back as [`ProgressEvent`]s, and
///   admission rejections surface as [`UepmmError::Rejected`].
///
/// See module docs.
pub struct ClusterBackend {
    inner: ClusterInner,
}

enum ClusterInner {
    Local(ClusterCore),
    Remote(RemoteClient),
}

impl ClusterBackend {
    /// Wrap a server whose workers are already registered (the TCP
    /// deployment: bind, `accept_workers`, then hand the server here).
    pub fn from_server(server: ClusterServer) -> ClusterBackend {
        ClusterBackend {
            inner: ClusterInner::Local(ClusterCore::new(
                "cluster",
                server,
                Vec::new(),
            )),
        }
    }

    /// Spawn an in-process loopback cluster with explicit server and
    /// worker configuration (pacing, deadline discipline, heartbeats)
    /// and a registration deadline for the worker threads.
    pub fn loopback(
        threads: usize,
        cluster: ClusterConfig,
        worker: WorkerConfig,
        accept_timeout: Duration,
    ) -> ApiResult<ClusterBackend> {
        Ok(ClusterBackend {
            inner: ClusterInner::Local(spawn_loopback_core(
                "cluster",
                threads,
                cluster,
                worker,
                accept_timeout,
            )?),
        })
    }

    /// Dial a multi-tenant serve plane at `addr` (e.g.
    /// `127.0.0.1:7077`) and open one client session named `client`.
    /// Speaks the wire-v6 client frames; an `OpenSession` ack carries
    /// the assigned session id, a `Reject` surfaces as
    /// [`UepmmError::Rejected`] with the plane's suggested backoff.
    pub fn connect(addr: &str, client: &str) -> ApiResult<ClusterBackend> {
        let conn = TcpConn::connect(addr)
            .map_err(|e| UepmmError::Transport(format!("dial {addr}: {e}")))?;
        Self::connect_over(Box::new(conn), client)
    }

    /// Open a client session over an already-established connection —
    /// how tests run the remote client against an in-process serve
    /// plane on the loopback transport.
    pub fn connect_over(
        conn: Box<dyn Connection>,
        client: &str,
    ) -> ApiResult<ClusterBackend> {
        Ok(ClusterBackend {
            inner: ClusterInner::Remote(RemoteClient::open(conn, client)?),
        })
    }

    /// Registry view of the attached workers (empty in remote-client
    /// mode: the fleet belongs to the serve plane).
    pub fn worker_info(&self) -> Vec<WorkerInfo> {
        match &self.inner {
            ClusterInner::Local(core) => core.server.worker_info(),
            ClusterInner::Remote(_) => Vec::new(),
        }
    }

    pub fn deadline_mode(&self) -> DeadlineMode {
        match &self.inner {
            ClusterInner::Local(core) => core.server.config().deadline,
            // the serve plane settles requests in virtual time
            ClusterInner::Remote(_) => DeadlineMode::Virtual,
        }
    }

    /// The session id the serve plane assigned (remote mode only).
    pub fn session_id(&self) -> Option<u64> {
        match &self.inner {
            ClusterInner::Local(_) => None,
            ClusterInner::Remote(rc) => Some(rc.session),
        }
    }
}

impl Backend for ClusterBackend {
    fn name(&self) -> &'static str {
        match &self.inner {
            ClusterInner::Local(_) => "cluster",
            ClusterInner::Remote(_) => "cluster-remote",
        }
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            deterministic: self.deadline_mode() == DeadlineMode::Virtual,
            networked: true,
            // the remote client absorbs plane progress frames one poll
            // at a time; the local coordinator completes on first poll
            streaming: matches!(self.inner, ClusterInner::Remote(_)),
            // workers may self-sample or report natural timing, so a
            // session latency model is optional here
            needs_injected_delays: false,
            selective_compute: false,
        }
    }

    fn submit(&mut self, prep: PreparedRequest) -> ApiResult<()> {
        match &mut self.inner {
            ClusterInner::Local(core) => core.submit(prep),
            ClusterInner::Remote(rc) => rc.submit(prep),
        }
    }

    fn poll(&mut self, id: u64) -> ApiResult<PollState> {
        match &mut self.inner {
            ClusterInner::Local(core) => core.poll(id),
            ClusterInner::Remote(rc) => rc.poll(id),
        }
    }

    fn cancel(&mut self, id: u64) -> ApiResult<Option<RunReport>> {
        match &mut self.inner {
            ClusterInner::Local(core) => core.cancel(id),
            ClusterInner::Remote(rc) => rc.cancel(id),
        }
    }

    fn maintain(&mut self) -> ApiResult<Maintenance> {
        match &mut self.inner {
            ClusterInner::Local(core) => core.maintain(),
            // no registry view from the client side of the plane
            ClusterInner::Remote(_) => Ok(Maintenance::default()),
        }
    }

    fn apply_worker_scales(&mut self, scales: &[(u64, f64)]) -> ApiResult<()> {
        match &mut self.inner {
            ClusterInner::Local(core) => {
                core.server.set_worker_scales(scales);
                Ok(())
            }
            // the plane runs its own per-lane estimates; a tenant's
            // client-side fit does not override fleet-wide accounting
            ClusterInner::Remote(_) => Ok(()),
        }
    }

    fn inject_straggle(&mut self, scales: &[(u64, f64)]) -> ApiResult<()> {
        match &mut self.inner {
            ClusterInner::Local(core) => {
                core.server.set_straggle_injection(scales);
                Ok(())
            }
            // one tenant cannot slow a shared plane's fleet
            ClusterInner::Remote(_) => Ok(()),
        }
    }

    fn shutdown(&mut self) -> ApiResult<()> {
        match &mut self.inner {
            ClusterInner::Local(core) => core.shutdown(),
            ClusterInner::Remote(rc) => rc.shutdown(),
        }
    }
}

// ================================================== remote-client mode

/// Client half of the wire-v6 serve-plane protocol: one open session,
/// any number of in-flight requests, progress frames demultiplexed by
/// `(session, request)`.
struct RemoteClient {
    conn: Box<dyn Connection>,
    session: u64,
    pending: Vec<RemoteRequest>,
    done: Vec<(u64, RunReport)>,
    /// Rejections awaiting their own handle's poll.
    rejected: Vec<(u64, UepmmError)>,
}

/// Client-side state of one submitted request: everything the final
/// [`RunReport`] needs that never crosses the wire (`c_true`, replan
/// provenance, the local clock).
struct RemoteRequest {
    id: u64,
    score: Option<ScoreRef>,
    cache_hit: Option<bool>,
    replans: Vec<super::adapt::ReplanEvent>,
    events: Vec<ProgressEvent>,
    reported: usize,
    start: Instant,
}

/// How long one `poll` waits for the plane before declaring it stalled.
const REMOTE_POLL_WAIT: Duration = Duration::from_secs(60);

impl RemoteClient {
    fn open(mut conn: Box<dyn Connection>, client: &str) -> ApiResult<RemoteClient> {
        conn.send(&Msg::OpenSession { session: 0, client: client.to_string() })
            .map_err(|e| UepmmError::Transport(format!("open-session: {e}")))?;
        match conn.recv_timeout(Some(REMOTE_POLL_WAIT)) {
            Ok(Some(Msg::OpenSession { session, .. })) => Ok(RemoteClient {
                conn,
                session,
                pending: Vec::new(),
                done: Vec::new(),
                rejected: Vec::new(),
            }),
            Ok(Some(Msg::Reject { retry_after, reason, .. })) => {
                Err(reject_error(retry_after, reason))
            }
            Ok(Some(other)) => Err(UepmmError::Transport(format!(
                "serve plane answered OpenSession with {}",
                other.name()
            ))),
            Ok(None) => Err(UepmmError::Transport(
                "serve plane did not ack OpenSession".to_string(),
            )),
            Err(e) => Err(UepmmError::Transport(format!("open-session: {e}"))),
        }
    }

    fn submit(&mut self, prep: PreparedRequest) -> ApiResult<()> {
        let PreparedRequest {
            id, part, cm, t_max, delays, work, score, cache_hit, replans,
        } = prep;
        let (enc, wb) = match work {
            PreparedWork::Encoded { enc, wb } => (enc, wb),
            PreparedWork::Blocks { .. } | PreparedWork::Rateless { .. } => {
                return Err(UepmmError::Config(
                    "the remote serve plane accepts materialized fixed-rate \
                     requests only (selective compute and rateless streams \
                     are local modes)"
                        .to_string(),
                ))
            }
        };
        if let Some(d) = &delays {
            if d.len() != enc.packets.len() {
                return Err(UepmmError::Config(format!(
                    "{} delays for {} jobs",
                    d.len(),
                    enc.packets.len()
                )));
            }
        }
        let rows: Vec<Vec<f64>> =
            enc.packets.iter().map(|p| p.coeff_row(&enc.space)).collect();
        let msg = Msg::Submit(SubmitMsg {
            session: self.session,
            request: id,
            t_max,
            paradigm: match part.paradigm {
                Paradigm::RowTimesCol => 0,
                Paradigm::ColTimesRow => 1,
            },
            dims: [
                part.n as u32,
                part.p as u32,
                part.m as u32,
                part.u as u32,
                part.h as u32,
                part.q as u32,
            ],
            n_total: enc.space.n_total as u32,
            n_classes: cm.n_classes as u32,
            class_of: cm.class_of.iter().map(|&c| c as u32).collect(),
            rows,
            wa: enc.wa.clone(),
            wb: wb.into_iter().map(Arc::new).collect(),
            delays: delays.unwrap_or_default(),
            gram: score.as_ref().map(|s| s.gram.clone()),
            energy: score.as_ref().map(|s| s.energy).unwrap_or(f64::NAN),
        });
        self.conn
            .send(&msg)
            .map_err(|e| UepmmError::Transport(format!("submit: {e}")))?;
        self.pending.push(RemoteRequest {
            id,
            score,
            cache_hit,
            replans,
            events: Vec::new(),
            reported: 0,
            start: Instant::now(), // lint:allow(no-wallclock-in-deterministic-paths) RunReport wall telemetry only; results never depend on it
        });
        Ok(())
    }

    fn poll(&mut self, id: u64) -> ApiResult<PollState> {
        if let Some(pos) = self.done.iter().position(|(d, _)| *d == id) {
            return Ok(PollState::Ready(self.done.swap_remove(pos).1));
        }
        if let Some(pos) = self.rejected.iter().position(|(d, _)| *d == id) {
            return Err(self.rejected.swap_remove(pos).1);
        }
        let Some(pos) = self.pending.iter().position(|r| r.id == id) else {
            return Err(UepmmError::Config(format!("unknown request id {id}")));
        };
        // hand out progress buffered by another request's poll first
        {
            let req = &mut self.pending[pos];
            if req.reported < req.events.len() {
                let new = req.events[req.reported..].to_vec();
                req.reported = req.events.len();
                return Ok(PollState::Pending(new));
            }
        }
        // absorb exactly one plane frame, demultiplexed by request id
        let msg = match self.conn.recv_timeout(Some(REMOTE_POLL_WAIT)) {
            Ok(Some(m)) => m,
            Ok(None) => {
                return Err(UepmmError::Transport(
                    "serve plane went silent mid-request".to_string(),
                ))
            }
            Err(e) => return Err(UepmmError::Transport(format!("poll: {e}"))),
        };
        match msg {
            Msg::ProgressFrame(p) => {
                let ev = ProgressEvent {
                    received: p.received as usize,
                    recovered: p.recovered as usize,
                    newly: p.newly as usize,
                    attempt: p.attempt,
                    loss: p.loss,
                    normalized_loss: p.normalized_loss,
                    elapsed: p.elapsed,
                };
                if let Some(req) =
                    self.pending.iter_mut().find(|r| r.id == p.request)
                {
                    req.events.push(ev);
                    if req.id == id {
                        req.reported = req.events.len();
                        return Ok(PollState::Pending(vec![ev]));
                    }
                }
                Ok(PollState::Pending(Vec::new()))
            }
            Msg::ClientResult(res) => {
                let rid = res.request;
                let Some(rpos) =
                    self.pending.iter().position(|r| r.id == rid)
                else {
                    return Ok(PollState::Pending(Vec::new()));
                };
                let req = self.pending.swap_remove(rpos);
                let report = Self::finish(req, res);
                if rid == id {
                    Ok(PollState::Ready(report))
                } else {
                    self.done.push((rid, report));
                    Ok(PollState::Pending(Vec::new()))
                }
            }
            Msg::Reject { request, retry_after, reason, .. } => {
                let err = reject_error(retry_after, reason);
                if request == id {
                    self.pending.retain(|r| r.id != id);
                    Err(err)
                } else {
                    self.pending.retain(|r| r.id != request);
                    self.rejected.push((request, err));
                    Ok(PollState::Pending(Vec::new()))
                }
            }
            other => Err(UepmmError::Transport(format!(
                "unexpected plane frame {}",
                other.name()
            ))),
        }
    }

    /// Assemble the final report: plane accounting plus everything that
    /// stayed local. Scored requests recompute the loss from `c_true`
    /// exactly as `score_outcome` does, so a scored remote run reports
    /// the same numbers as a local one.
    fn finish(req: RemoteRequest, res: ClientResultMsg) -> RunReport {
        let (loss, normalized_loss) = match &req.score {
            Some(s) => {
                let loss = s.c_true.frob_sq_diff(&res.c_hat);
                let energy = s.c_true.frob_sq();
                (loss, if energy > 0.0 { loss / energy } else { 0.0 })
            }
            None => (res.loss, res.normalized_loss),
        };
        let outcome = Outcome {
            received: res.received as usize,
            recovered: res.recovered as usize,
            per_class_recovered: res.per_class.iter().map(|&c| c as usize).collect(),
            c_hat: res.c_hat,
            loss,
            normalized_loss,
        };
        RunReport {
            outcome,
            late: res.late as usize,
            dispatched: res.dispatched as usize,
            retries: res.retries as usize,
            corrupt: res.corrupt as usize,
            verify_failures: res.verify_failures as usize,
            // the plane quarantines fleet-side; not visible per client
            quarantined: 0,
            wall: req.start.elapsed(),
            cache_hit: req.cache_hit,
            backend: "cluster-remote",
            // per-job timings stay plane-side (fleet telemetry)
            timings: Vec::new(),
            worker_packets: Vec::new(),
            partial_packets: 0,
            progress: Progress::from_events(req.events, req.replans),
        }
    }

    fn cancel(&mut self, id: u64) -> ApiResult<Option<RunReport>> {
        if let Some(pos) = self.done.iter().position(|(d, _)| *d == id) {
            return Ok(Some(self.done.swap_remove(pos).1));
        }
        if let Some(pos) = self.rejected.iter().position(|(d, _)| *d == id) {
            self.rejected.swap_remove(pos);
            return Ok(None);
        }
        // the plane settles every admitted request; "cancel" here means
        // the client stops listening — late frames for the id are
        // dropped by the demultiplexer once the entry is gone
        self.pending.retain(|r| r.id != id);
        Ok(None)
    }

    fn shutdown(&mut self) -> ApiResult<()> {
        self.conn
            .send(&Msg::CloseSession { session: self.session })
            .map_err(|e| UepmmError::Transport(format!("close-session: {e}")))?;
        // drain until the close echo so in-flight results are not cut off
        loop {
            match self.conn.recv_timeout(Some(REMOTE_POLL_WAIT)) {
                Ok(Some(Msg::CloseSession { .. })) | Err(WireError::Closed) => {
                    return Ok(())
                }
                Ok(Some(_)) => {}
                Ok(None) => {
                    return Err(UepmmError::Transport(
                        "serve plane did not ack CloseSession".to_string(),
                    ))
                }
                Err(e) => {
                    return Err(UepmmError::Transport(format!(
                        "close-session: {e}"
                    )))
                }
            }
        }
    }
}

fn reject_error(retry_after: f64, reason: String) -> UepmmError {
    UepmmError::Rejected {
        retry_after_ms: (retry_after * 1000.0).max(0.0) as u64,
        reason,
    }
}

// ==================================================== shared backend

/// A cloneable handle sharing one backend between several
/// [`super::Session`]s.
///
/// A session is bound to one plan (partitioning, code, workers), but a
/// DNN training loop multiplies several distinct shapes per step — one
/// session per shape — and wants all of them riding the *same* warm
/// worker fleet, with straggle telemetry and fitted scales accumulating
/// across shapes instead of resetting per session. `SharedBackend` wraps
/// any backend in a reference-counted handle implementing [`Backend`]
/// by delegation, so each session holds a clone.
///
/// Teardown is explicit and single: [`Backend::shutdown`] on a *handle*
/// is a no-op (a session consuming its clone must not kill the fleet
/// under its siblings); call [`SharedBackend::shutdown_inner`] once
/// when the whole training run ends.
pub struct SharedBackend {
    name: &'static str,
    caps: Capabilities,
    inner: Arc<Mutex<Box<dyn Backend>>>,
}

impl Clone for SharedBackend {
    fn clone(&self) -> SharedBackend {
        SharedBackend {
            name: self.name,
            caps: self.caps,
            inner: Arc::clone(&self.inner),
        }
    }
}

impl std::fmt::Debug for SharedBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SharedBackend").field("name", &self.name).finish()
    }
}

impl SharedBackend {
    pub fn new(backend: impl Backend + 'static) -> SharedBackend {
        let name = backend.name();
        let caps = backend.capabilities();
        SharedBackend { name, caps, inner: Arc::new(Mutex::new(Box::new(backend))) }
    }

    /// Delegation guard; a poisoned lock (a sibling session panicked
    /// mid-call) yields the inner state anyway — backends keep their
    /// own invariants and the alternative is deadlocking teardown.
    fn guard(&self) -> std::sync::MutexGuard<'_, Box<dyn Backend>> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Tear down the shared backend itself (graceful worker shutdown on
    /// cluster backends). Call once, after every session sharing the
    /// handle is done.
    pub fn shutdown_inner(&self) -> ApiResult<()> {
        self.guard().shutdown()
    }
}

impl Backend for SharedBackend {
    fn name(&self) -> &'static str {
        self.name
    }

    fn capabilities(&self) -> Capabilities {
        self.caps
    }

    fn submit(&mut self, prep: PreparedRequest) -> ApiResult<()> {
        self.guard().submit(prep)
    }

    fn poll(&mut self, id: u64) -> ApiResult<PollState> {
        self.guard().poll(id)
    }

    fn cancel(&mut self, id: u64) -> ApiResult<Option<RunReport>> {
        self.guard().cancel(id)
    }

    fn maintain(&mut self) -> ApiResult<Maintenance> {
        self.guard().maintain()
    }

    fn apply_worker_scales(&mut self, scales: &[(u64, f64)]) -> ApiResult<()> {
        self.guard().apply_worker_scales(scales)
    }

    fn inject_straggle(&mut self, scales: &[(u64, f64)]) -> ApiResult<()> {
        self.guard().inject_straggle(scales)
    }

    fn shutdown(&mut self) -> ApiResult<()> {
        // a handle going away must not kill the fleet under sibling
        // sessions; see the type docs
        Ok(())
    }
}
