//! The typed error of the public client API.
//!
//! `anyhow` remains the error currency *inside* the crate (plan
//! preparation, wire protocol, worker loops); at the [`crate::api`]
//! boundary every failure is classified into one [`UepmmError`] variant
//! so callers can branch on what went wrong instead of string-matching a
//! context chain.

/// `Result` specialized to the API boundary.
pub type ApiResult<T> = std::result::Result<T, UepmmError>;

/// Everything the unified client API can fail with.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum UepmmError {
    /// Invalid session or request configuration, caught before any work
    /// is dispatched (missing builder fields, shape mismatches, a
    /// backend asked for a mode it does not support, unknown handles).
    Config(String),
    /// Plan preparation failed: splitting the operands, classifying by
    /// norm, drawing the coded packet set, or materializing `W_A`.
    Encode(String),
    /// A worker's coded sub-product computation failed (engine error).
    Compute(String),
    /// Transport or registry failure: no live workers, dropped
    /// connections, a worker pool that failed to assemble.
    Transport(String),
    /// The deadline was rejected (non-finite or negative `T_max`) or
    /// deadline bookkeeping could not be honored.
    Deadline(String),
    /// Decoding or assembling `Ĉ` from the collected results failed.
    Decode(String),
    /// Result integrity violated: a quarantined worker tried to rejoin,
    /// or verification bookkeeping could not be honored.
    Integrity(String),
    /// The serve plane refused admission (session table or request
    /// queue saturated). Back off for `retry_after_ms` and redial — the
    /// rejection is load shedding, not a protocol fault.
    Rejected { retry_after_ms: u64, reason: String },
}

impl UepmmError {
    /// The variant name, for logs and metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            UepmmError::Config(_) => "config",
            UepmmError::Encode(_) => "encode",
            UepmmError::Compute(_) => "compute",
            UepmmError::Transport(_) => "transport",
            UepmmError::Deadline(_) => "deadline",
            UepmmError::Decode(_) => "decode",
            UepmmError::Integrity(_) => "integrity",
            UepmmError::Rejected { .. } => "rejected",
        }
    }

    /// The human-readable message carried by the variant.
    pub fn message(&self) -> &str {
        match self {
            UepmmError::Config(m)
            | UepmmError::Encode(m)
            | UepmmError::Compute(m)
            | UepmmError::Transport(m)
            | UepmmError::Deadline(m)
            | UepmmError::Decode(m)
            | UepmmError::Integrity(m) => m,
            UepmmError::Rejected { reason, .. } => reason,
        }
    }
}

impl std::fmt::Display for UepmmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            UepmmError::Rejected { retry_after_ms, reason } => write!(
                f,
                "rejected: {reason} (retry after {retry_after_ms} ms)"
            ),
            _ => write!(f, "{}: {}", self.kind(), self.message()),
        }
    }
}

impl std::error::Error for UepmmError {}

/// Classify an internal `anyhow` error escaping a cluster-backed run.
/// Validation messages stay `Config`; everything else on that path is a
/// transport/registry failure.
pub(crate) fn classify_cluster_error(e: anyhow::Error) -> UepmmError {
    let msg = format!("{e:#}");
    if msg.contains("one job per packet")
        || msg.contains("one injected delay per job")
        || msg.contains("time_scale")
    {
        UepmmError::Config(msg)
    } else if msg.contains("quarantin") {
        UepmmError::Integrity(msg)
    } else {
        UepmmError::Transport(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_kind_and_message() {
        let e = UepmmError::Deadline("T_max must be finite".to_string());
        assert_eq!(e.kind(), "deadline");
        assert_eq!(format!("{e}"), "deadline: T_max must be finite");
    }

    #[test]
    fn cluster_errors_classify_config_vs_transport() {
        let cfg = classify_cluster_error(anyhow::anyhow!("one job per packet"));
        assert!(matches!(cfg, UepmmError::Config(_)));
        let tr = classify_cluster_error(anyhow::anyhow!(
            "no live workers registered with the coordinator"
        ));
        assert!(matches!(tr, UepmmError::Transport(_)));
    }

    #[test]
    fn rejected_carries_backoff_and_reason() {
        let e = UepmmError::Rejected {
            retry_after_ms: 250,
            reason: "session table full".to_string(),
        };
        assert_eq!(e.kind(), "rejected");
        assert_eq!(e.message(), "session table full");
        assert_eq!(
            format!("{e}"),
            "rejected: session table full (retry after 250 ms)"
        );
    }

    #[test]
    fn quarantine_refusals_classify_as_integrity() {
        let e = classify_cluster_error(anyhow::anyhow!(
            "agent byz is quarantined (worker 3): rejoin refused until reset_quarantine"
        ));
        assert!(matches!(e, UepmmError::Integrity(_)));
        assert_eq!(e.kind(), "integrity");
    }
}
