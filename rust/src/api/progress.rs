//! The anytime result stream: one [`ProgressEvent`] per decode
//! refinement, so callers consume a progressively improving `Ĉ(t)`
//! instead of waiting for the final outcome.
//!
//! The paper's central promise is that a UEP-coded multiplication is an
//! *anytime* approximation — the parameter server can stop at any
//! moment with the best `Ĉ` so far. Every backend reports each absorbed
//! in-deadline result as an event carrying the running recovered count
//! and (for scored requests) the running residual loss, maintained
//! incrementally through [`crate::partition::Partitioning::loss_delta_on_recover`]
//! exactly like the Monte-Carlo sweep engine.

use crate::partition::Partitioning;

use super::adapt::ReplanEvent;
use super::session::ScoreRef;

/// One decode refinement inside a served request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ProgressEvent {
    /// Results absorbed so far (this event's arrival included).
    pub received: usize,
    /// Real sub-products determined so far.
    pub recovered: usize,
    /// Sub-products newly determined by this arrival (0 for a
    /// rank-redundant packet).
    pub newly: usize,
    /// Dispatch attempt that produced this arrival: 0 for a first send,
    /// `n` for the `n`-th re-dispatch after a worker death (always 0 on
    /// in-process backends, which have no workers to lose).
    pub attempt: u32,
    /// Running residual loss `‖C − Ĉ‖²_F` (NaN for unscored requests).
    pub loss: f64,
    /// Running loss normalized by `‖C‖²_F` (NaN for unscored requests).
    pub normalized_loss: f64,
    /// Virtual completion time of this arrival (same units as `T_max`).
    pub elapsed: f64,
}

/// The recorded refinement stream of one request.
#[derive(Clone, Debug, Default)]
pub struct Progress {
    events: Vec<ProgressEvent>,
    replans: Vec<ReplanEvent>,
}

impl Progress {
    /// Assemble a stream from already-shaped events — the remote-client
    /// path, where refinements arrive as wire `ProgressFrame`s instead
    /// of being recorded through a local [`ProgressTracker`].
    pub(crate) fn from_events(
        events: Vec<ProgressEvent>,
        replans: Vec<ReplanEvent>,
    ) -> Progress {
        Progress { events, replans }
    }

    /// All events, in absorption order.
    pub fn events(&self) -> &[ProgressEvent] {
        &self.events
    }

    /// Replan decisions taken between the previous request and this one
    /// (adaptive sessions only; see [`super::SessionBuilder::adaptive`]).
    /// The plan this request was served under is the result of the last
    /// event here.
    pub fn replans(&self) -> &[ReplanEvent] {
        &self.replans
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn last(&self) -> Option<&ProgressEvent> {
        self.events.last()
    }

    /// Events that actually refined `Ĉ` (recovered at least one new
    /// sub-product).
    pub fn refinements(&self) -> usize {
        self.events.iter().filter(|e| e.newly > 0).count()
    }

    /// `true` when the running loss never increases across consecutive
    /// scored events (vacuously true for unscored streams). For the r×c
    /// paradigm the Gram matrix is diagonal, so this holds by
    /// construction; for c×r it is the paper's empirical behavior.
    pub fn loss_non_increasing(&self) -> bool {
        self.events
            .windows(2)
            .filter(|w| w[0].loss.is_finite() && w[1].loss.is_finite())
            .all(|w| w[1].loss <= w[0].loss + 1e-9 * (1.0 + w[0].loss.abs()))
    }
}

impl IntoIterator for Progress {
    type Item = ProgressEvent;
    type IntoIter = std::vec::IntoIter<ProgressEvent>;

    fn into_iter(self) -> Self::IntoIter {
        self.events.into_iter()
    }
}

/// Shared event recorder used by every backend: maintains the recovered
/// mask and the running Gram-based residual loss, and buffers events so
/// `poll` can hand out only the ones not yet reported.
pub(crate) struct ProgressTracker {
    part: Partitioning,
    gram: Option<crate::linalg::Matrix>,
    energy: f64,
    mask: Vec<bool>,
    loss: f64,
    events: Vec<ProgressEvent>,
    replans: Vec<ReplanEvent>,
    reported: usize,
}

impl ProgressTracker {
    pub(crate) fn new(part: &Partitioning, score: Option<&ScoreRef>) -> Self {
        let k = part.num_products();
        let (gram, energy, loss) = match score {
            Some(s) => (Some(s.gram.clone()), s.energy, s.energy),
            None => (None, f64::NAN, f64::NAN),
        };
        ProgressTracker {
            part: part.clone(),
            gram,
            energy,
            mask: vec![false; k],
            loss,
            events: Vec::new(),
            replans: Vec::new(),
            reported: 0,
        }
    }

    /// Attach the replan decisions this request was prepared under (the
    /// session drains its pending events into the prepared request; the
    /// backend seeds its tracker with them here).
    pub(crate) fn seed_replans(&mut self, replans: &[ReplanEvent]) {
        self.replans.extend_from_slice(replans);
    }

    /// Record one absorbed in-deadline arrival.
    pub(crate) fn record(
        &mut self,
        elapsed: f64,
        received: usize,
        recovered: usize,
        newly: &[usize],
        attempt: u32,
    ) {
        if let Some(gram) = &self.gram {
            for &u in newly {
                self.mask[u] = true;
                self.loss -= self.part.loss_delta_on_recover(gram, &self.mask, u);
            }
            if recovered == self.part.num_products() {
                // pin the fully-decoded endpoint to exactly zero,
                // shedding running-sum rounding (as the sweep engine does)
                self.loss = 0.0;
            }
        }
        let normalized = if self.energy > 0.0 { self.loss / self.energy } else { self.loss };
        self.events.push(ProgressEvent {
            received,
            recovered,
            newly: newly.len(),
            attempt,
            loss: self.loss,
            normalized_loss: normalized,
            elapsed,
        });
    }

    /// Events recorded since the last `take_new` call (for streaming
    /// `poll` consumers).
    pub(crate) fn take_new(&mut self) -> Vec<ProgressEvent> {
        let new = self.events[self.reported..].to_vec();
        self.reported = self.events.len();
        new
    }

    pub(crate) fn finish(self) -> Progress {
        Progress { events: self.events, replans: self.replans }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(received: usize, recovered: usize, newly: usize, loss: f64) -> ProgressEvent {
        ProgressEvent {
            received,
            recovered,
            newly,
            attempt: 0,
            loss,
            normalized_loss: loss,
            elapsed: received as f64,
        }
    }

    #[test]
    fn refinement_and_monotonicity_accessors() {
        let p = Progress {
            events: vec![ev(1, 1, 1, 0.8), ev(2, 1, 0, 0.8), ev(3, 3, 2, 0.1)],
            replans: Vec::new(),
        };
        assert_eq!(p.len(), 3);
        assert_eq!(p.refinements(), 2);
        assert!(p.loss_non_increasing());
        assert_eq!(p.last().unwrap().recovered, 3);

        let bad = Progress {
            events: vec![ev(1, 1, 1, 0.2), ev(2, 2, 1, 0.5)],
            replans: Vec::new(),
        };
        assert!(!bad.loss_non_increasing());
    }

    #[test]
    fn unscored_streams_are_vacuously_monotone() {
        let p = Progress {
            events: vec![ev(1, 1, 1, f64::NAN), ev(2, 2, 1, f64::NAN)],
            replans: Vec::new(),
        };
        assert!(p.loss_non_increasing());
        assert_eq!(p.refinements(), 2);
    }
}
