//! The adaptive planning loop: live timing telemetry → fitted latency
//! model → window-polynomial re-optimization.
//!
//! The paper closes §VI noting the window selection distributions are
//! chosen "arbitrarily" and "can be optimized to minimize the loss" —
//! [`crate::analysis::optimize_gamma`] implements that optimization, but
//! against an *assumed* [`LatencyModel`]. This module feeds it reality:
//! a [`Replanner`] folds the per-job round-trip times every served
//! request reports ([`super::RunReport::timings`]) into a
//! [`FleetEstimator`], periodically fits the model the cluster is
//! actually exhibiting, and re-runs the optimizer against it under the
//! live importance classification. An adaptive [`super::Session`]
//! (opt-in via [`super::SessionBuilder::adaptive`]) swaps the winning Γ
//! into its code spec between requests and reports each decision as a
//! [`ReplanEvent`] in the next request's [`super::Progress`] stream.
//!
//! The optimizer consumes the *fleet-wide* fit: the Theorem 2/3 loss
//! formulas model i.i.d. workers, and the pooled per-job sample already
//! reflects a heterogeneous fleet's mixture. The per-worker scale
//! offsets the [`FleetEstimator`] also maintains are operator telemetry
//! ([`super::Session::worker_scales`]) — shedding load from individual
//! stragglers is the cluster dispatcher's job, which keys on the same
//! EWMA server-side.
//!
//! Determinism: a replan decision is a pure function of the observed
//! timing stream, so `Virtual`-time sessions replan bit-identically
//! across runs and thread counts.

use crate::analysis::{optimize_gamma, GammaOpt, TheoremLoss, UepStrategy};
use crate::latency::{FleetEstimator, LatencyModel};
use crate::linalg::Matrix;
use crate::partition::{ClassMap, Partitioning};

/// When and how an adaptive session re-optimizes its plan.
#[derive(Clone, Debug)]
pub struct ReplanPolicy {
    /// Re-optimize after every `every` completed requests (≥ 1).
    pub every: usize,
    /// Do not fit before this many timing samples have been observed
    /// (an early fit over two or three arrivals is noise).
    pub min_samples: u64,
    /// Optimizer sweeps per replan (see
    /// [`crate::analysis::optimize_gamma`]; the objective is
    /// low-dimensional, a handful suffices).
    pub sweeps: usize,
    /// Deadline the window polynomial is optimized for; `None` uses the
    /// session's default deadline.
    pub t_star: Option<f64>,
    /// Also re-classify pinned importance classes from the next
    /// request's actual block norms (sessions with auto classes already
    /// re-classify per request). A changed class map purges the encode
    /// cache — an unchanged one leaves it untouched.
    pub reband: bool,
}

impl Default for ReplanPolicy {
    fn default() -> Self {
        ReplanPolicy {
            every: 4,
            min_samples: 8,
            sweeps: 4,
            t_star: None,
            reband: false,
        }
    }
}

impl ReplanPolicy {
    /// Policy that replans after every `every` completed requests.
    pub fn every(every: usize) -> ReplanPolicy {
        ReplanPolicy { every: every.max(1), ..ReplanPolicy::default() }
    }
}

/// One replan decision, surfaced in the progress stream of the first
/// request served under the new plan.
#[derive(Clone, Debug, PartialEq)]
pub struct ReplanEvent {
    /// Completed requests when the decision was taken.
    pub after_requests: usize,
    /// Timing samples the fit was based on.
    pub samples: u64,
    /// The fitted latency model that drove the decision.
    pub model: LatencyModel,
    pub gamma_before: Vec<f64>,
    pub gamma_after: Vec<f64>,
    /// Predicted normalized loss at the target deadline under the old /
    /// new window polynomial (Theorem 2/3 under the fitted model).
    pub predicted_before: f64,
    pub predicted_after: f64,
    /// Whether re-banding changed the importance-class assignment (and
    /// therefore purged the encode cache).
    pub classes_changed: bool,
}

/// The stateful half of the adaptive loop: telemetry in, re-optimized
/// window polynomials out. Owned by an adaptive [`super::Session`]; also
/// usable standalone by anything that holds
/// [`crate::cluster::JobTiming`] records.
pub struct Replanner {
    policy: ReplanPolicy,
    strategy: UepStrategy,
    fleet: FleetEstimator,
    completed: usize,
    since_replan: usize,
    replans: usize,
}

impl Replanner {
    /// `strategy` follows the session's code kind (NOW vs EW); `omega`
    /// is the Ω the observed delays are scaled by.
    pub fn new(policy: ReplanPolicy, strategy: UepStrategy, omega: f64) -> Replanner {
        Replanner {
            policy,
            strategy,
            fleet: FleetEstimator::new(omega),
            completed: 0,
            since_replan: 0,
            replans: 0,
        }
    }

    /// Fold in one per-job timing record (late results are completion
    /// times too — stragglers are exactly the signal). `Wall`-mode
    /// streams never see post-grace stragglers, so their fit is
    /// right-censored; `Virtual` streams observe everything.
    pub fn observe_timing(&mut self, worker: u64, delay: f64) {
        self.fleet.observe(worker, delay);
    }

    /// Absorb a registry straggle snapshot
    /// (see [`super::Maintenance::straggle`]).
    pub fn observe_straggle(&mut self, snapshot: &[(u64, Option<f64>)]) {
        self.fleet.absorb_straggle(snapshot);
    }

    /// Count one completed request toward the replan cadence.
    pub fn note_completed(&mut self) {
        self.completed += 1;
        self.since_replan += 1;
    }

    /// Whether the next prepared request should replan first.
    pub fn due(&self) -> bool {
        self.since_replan >= self.policy.every.max(1)
            && self.fleet.observations() >= self.policy.min_samples
    }

    pub fn policy(&self) -> &ReplanPolicy {
        &self.policy
    }

    pub fn fleet(&self) -> &FleetEstimator {
        &self.fleet
    }

    /// The latency model currently fitted to the observed timings.
    pub fn fitted(&self) -> Option<LatencyModel> {
        self.fleet.fleet().fit()
    }

    /// Replans performed so far.
    pub fn replans(&self) -> usize {
        self.replans
    }

    /// Requests observed so far.
    pub fn completed(&self) -> usize {
        self.completed
    }

    /// Run one replan: fit the model, rebuild the Theorem 2/3 objective
    /// under the live classification and estimated per-class variances,
    /// and re-optimize Γ for `t_star`. Returns `None` when no model can
    /// be fitted yet. Resets the cadence either way.
    pub fn replan(
        &mut self,
        part: &Partitioning,
        cm: &ClassMap,
        sigma2: Vec<f64>,
        gamma: Vec<f64>,
        workers: usize,
        omega: f64,
        t_star: f64,
    ) -> Option<(LatencyModel, GammaOpt)> {
        self.since_replan = 0;
        let model = self.fitted()?;
        let th = TheoremLoss::for_plan(
            part,
            cm,
            sigma2,
            gamma,
            workers,
            model.clone(),
            omega,
        );
        let opt = optimize_gamma(&th, self.strategy, t_star, self.policy.sweeps);
        self.replans += 1;
        Some((model, opt))
    }
}

/// Estimate the per-class variance products `σ²_{l,A}·σ²_{l,B}` from the
/// operands' actual block norms: under Assumption 1,
/// `E‖A_i‖²_F = numel·σ²_A`, so the per-entry mean square of each factor
/// block estimates its variance and the class estimate averages the
/// products over the class members. This is the "live importance
/// classification" side of the replan objective — no reference product
/// is computed.
pub fn estimate_class_sigma2(
    part: &Partitioning,
    cm: &ClassMap,
    a: &Matrix,
    b: &Matrix,
) -> Vec<f64> {
    let a_norms: Vec<f64> = part.split_a(a).iter().map(|m| m.frob_sq()).collect();
    let b_norms: Vec<f64> = part.split_b(b).iter().map(|m| m.frob_sq()).collect();
    class_sigma2_from_norms(
        part,
        cm,
        &a_norms,
        &b_norms,
        (a.rows() * a.cols() / a_norms.len()) as f64,
        (b.rows() * b.cols() / b_norms.len()) as f64,
    )
}

/// [`estimate_class_sigma2`] from already-computed per-block Frobenius
/// norms (callers that also classify by norm split each operand once
/// and feed both consumers). `a_numel`/`b_numel` are the entries per
/// factor block — blocks of a side share a shape in both paradigms.
pub fn class_sigma2_from_norms(
    part: &Partitioning,
    cm: &ClassMap,
    a_norms: &[f64],
    b_norms: &[f64],
    a_numel: f64,
    b_numel: f64,
) -> Vec<f64> {
    cm.members
        .iter()
        .map(|members| {
            let sum: f64 = members
                .iter()
                .map(|&u| {
                    let (ai, bi) = part.factors_of(u);
                    (a_norms[ai] / a_numel) * (b_norms[bi] / b_numel)
                })
                .sum();
            sum / members.len().max(1) as f64
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn cadence_waits_for_samples_and_resets_on_replan() {
        let policy = ReplanPolicy { every: 2, min_samples: 4, ..Default::default() };
        let mut rp = Replanner::new(policy, UepStrategy::Ew, 1.0);
        rp.note_completed();
        rp.note_completed();
        assert!(!rp.due(), "no samples yet");
        for w in 0..4u64 {
            rp.observe_timing(w, 0.5 + w as f64 * 0.1);
        }
        assert!(rp.due());

        let part = Partitioning::rxc(3, 3, 2, 3, 2);
        let pair = crate::partition::default_pair_classes(3);
        let cm = ClassMap::from_levels(&part, vec![0, 1, 2], vec![0, 1, 2], &pair);
        let got = rp.replan(
            &part,
            &cm,
            vec![40.0, 1.0, 0.07],
            vec![0.4, 0.35, 0.25],
            30,
            0.3,
            0.5,
        );
        assert!(got.is_some());
        assert_eq!(rp.replans(), 1);
        assert!(!rp.due(), "cadence must reset after a replan");
    }

    #[test]
    fn replanning_under_a_slower_fitted_model_shifts_mass_to_window_zero() {
        // Feed timings drawn from a much slower fleet than the paper's
        // Exp(1): the fitted model should push the optimizer to protect
        // the heavy class harder than Table III does.
        let mut rp = Replanner::new(ReplanPolicy::every(1), UepStrategy::Ew, 0.3);
        let slow = LatencyModel::exp(0.3);
        let mut rng = Pcg64::seed_from(5);
        for i in 0..400u64 {
            rp.observe_timing(i % 30, slow.sample_scaled(0.3, &mut rng));
        }
        rp.note_completed();
        assert!(rp.due());
        let part = Partitioning::rxc(3, 3, 50, 150, 50);
        let pair = crate::partition::default_pair_classes(3);
        let cm = ClassMap::from_levels(&part, vec![0, 1, 2], vec![0, 1, 2], &pair);
        let (model, opt) = rp
            .replan(
                &part,
                &cm,
                vec![40.0, 1.0, 0.07],
                vec![0.40, 0.35, 0.25],
                30,
                0.3,
                0.5,
            )
            .unwrap();
        match model {
            LatencyModel::Exponential { lambda } => {
                assert!((lambda - 0.3).abs() < 0.05, "fitted λ {lambda}")
            }
            other => panic!("expected an exponential fit, got {other:?}"),
        }
        assert!(opt.loss <= opt.initial_loss + 1e-12);
        assert!(
            opt.gamma[0] > 0.40,
            "scarcer arrivals must favor window 0: {:?}",
            opt.gamma
        );
    }

    #[test]
    fn sigma2_estimate_tracks_the_planted_level_variances() {
        let spec = crate::config::SyntheticSpec::fig9_rxc().scaled(6);
        let mut rng = Pcg64::seed_from(9);
        let (a, b) = spec.sample_matrices(&mut rng);
        let cm = spec.class_map();
        let est = estimate_class_sigma2(&spec.part, &cm, &a, &b);
        let truth = spec.class_sigma2(); // [40, 1, 0.07] per class merge
        for (e, t) in est.iter().zip(truth.iter()) {
            assert!(
                (e / t - 1.0).abs() < 0.35,
                "estimate {e} vs planted {t} (all: {est:?} vs {truth:?})"
            );
        }
    }
}
