//! Benchmark harness (`cargo bench [-- <filter>]`).
//!
//! Criterion is not available offline, so this is a self-contained
//! harness: adaptive iteration counts, warmup, median/MAD over samples,
//! one bench per paper table/figure pipeline plus the system hot paths
//! (encode, decode, matmul, coordinator, PJRT artifact execution).
//! Results are printed as a table and appended to `results/bench.csv`.

use std::time::{Duration, Instant};

use uepmm::coding::{CodeKind, CodeSpec, DecodeState, EncodeStyle, UnknownSpace};
use uepmm::config::SyntheticSpec;
use uepmm::coordinator::{build_job_matrices, Coordinator, Plan};
use uepmm::data::synthetic_digits;
use uepmm::experiments::mc_loss_vs_time;
use uepmm::latency::LatencyModel;
use uepmm::linalg::{matmul_naive, matmul_with, Matrix, MatmulOpts};
use uepmm::nn::{
    CodedMatmulCfg, DistributedMatmul, MatmulStrategy, Mlp, TauSchedule,
};
use uepmm::partition::{Paradigm, Partitioning};
use uepmm::rng::Pcg64;
use uepmm::runtime::{ExecEngine, NativeEngine, PjrtEngine};
use uepmm::sim::{
    loss_trace_packets_scratch, LossTracePoint, StragglerSim, SweepScratch,
};
use uepmm::util::csv::CsvTable;
use uepmm::util::json::Json;
use uepmm::util::pool::available_parallelism;

/// One benchmark result.
struct BenchResult {
    name: String,
    median: Duration,
    mad: Duration,
    samples: usize,
    iters_per_sample: usize,
}

struct Harness {
    /// Substring filters; a bench runs when any filter matches (or none
    /// were given). Multiple filters let one invocation cover several
    /// groups — e.g. `cargo bench -- hot sweep` — so results/BENCH.json
    /// holds them all instead of the last run clobbering the file.
    filters: Vec<String>,
    results: Vec<BenchResult>,
}

impl Harness {
    fn new() -> Self {
        let filters: Vec<String> = std::env::args()
            .skip(1)
            .filter(|a| !a.starts_with("--") && !a.is_empty())
            .collect();
        Harness { filters, results: Vec::new() }
    }

    /// True when `name` passes the CLI filters.
    fn wants(&self, name: &str) -> bool {
        self.filters.is_empty()
            || self.filters.iter().any(|filt| name.contains(filt.as_str()))
    }

    /// Time `f`, autoscaling iterations to ~25 ms per sample, 9 samples.
    fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) {
        if !self.wants(name) {
            return;
        }
        // warmup + calibration
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let target = Duration::from_millis(25);
        let iters = (target.as_nanos() / once.as_nanos()).clamp(1, 100_000) as usize;
        let samples = 9;
        let mut times: Vec<Duration> = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            times.push(t.elapsed() / iters as u32);
        }
        times.sort();
        let median = times[samples / 2];
        let mad = {
            let mut devs: Vec<Duration> = times
                .iter()
                .map(|&t| if t > median { t - median } else { median - t })
                .collect();
            devs.sort();
            devs[samples / 2]
        };
        println!(
            "{name:<52} {:>12} ±{:>10}  ({} iters × {} samples)",
            fmt_dur(median),
            fmt_dur(mad),
            iters,
            samples
        );
        self.results.push(BenchResult {
            name: name.to_string(),
            median,
            mad,
            samples,
            iters_per_sample: iters,
        });
    }

    /// Record an externally measured latency point (e.g. a served-request
    /// percentile from a concurrent run) so it lands in the same CSV/JSON
    /// perf trajectory as the timed benches. `samples` is the number of
    /// observations the point was taken over.
    fn record(&mut self, name: &str, value: Duration, samples: usize) {
        if !self.wants(name) {
            return;
        }
        println!(
            "{name:<52} {:>12} ±{:>10}  (1 iters × {samples} samples)",
            fmt_dur(value),
            fmt_dur(Duration::ZERO),
        );
        self.results.push(BenchResult {
            name: name.to_string(),
            median: value,
            mad: Duration::ZERO,
            samples,
            iters_per_sample: 1,
        });
    }

    fn write_csv(&self) {
        let mut t = CsvTable::new(&["bench", "median_ns", "mad_ns", "iters", "samples"]);
        for r in &self.results {
            t.push_raw(vec![
                r.name.clone(),
                r.median.as_nanos().to_string(),
                r.mad.as_nanos().to_string(),
                r.iters_per_sample.to_string(),
                r.samples.to_string(),
            ]);
        }
        if let Err(e) = t.write("results/bench.csv") {
            eprintln!("could not write results/bench.csv: {e}");
        } else {
            println!("\nwrote results/bench.csv ({} rows)", self.results.len());
        }
    }

    /// Machine-readable perf trajectory: `{"<bench name>": median_ns}`.
    /// Consumed by CI and EXPERIMENTS.md to diff perf across PRs.
    fn write_json(&self) {
        let pairs: Vec<(&str, Json)> = self
            .results
            .iter()
            .map(|r| (r.name.as_str(), Json::Num(r.median.as_nanos() as f64)))
            .collect();
        let s = format!("{}\n", Json::obj(pairs));
        if let Err(e) =
            std::fs::create_dir_all("results").and_then(|_| std::fs::write("results/BENCH.json", s))
        {
            eprintln!("could not write results/BENCH.json: {e}");
        } else {
            println!("wrote results/BENCH.json ({} entries)", self.results.len());
        }
    }
}

/// The pre-refactor sweep inner loop, kept verbatim as the baseline the
/// `sweep/` benches compare against: per-arrival full-mask recount and
/// full `Σ_{i,j∉rec} G_ij` Gram recompute (no scratch reuse, fresh
/// decode allocations every call).
fn loss_trace_reference(
    part: &Partitioning,
    spec: &CodeSpec,
    gram: &uepmm::linalg::Matrix,
    packets: &[uepmm::coding::Packet],
    arrivals: &[f64],
) -> Vec<LossTracePoint> {
    let space = UnknownSpace::for_code(part, spec.style);
    let mut st = DecodeState::new(space);
    let mut order: Vec<usize> = (0..arrivals.len()).collect();
    order.sort_by(|&a, &b| arrivals[a].total_cmp(&arrivals[b]));
    let mut mask = vec![false; part.num_products()];
    let mut trace = vec![LossTracePoint {
        time: 0.0,
        received: 0,
        recovered: 0,
        loss: part.loss_from_gram(gram, &mask),
    }];
    for (i, &w) in order.iter().enumerate() {
        for u in st.add_packet(&packets[w], None) {
            mask[u] = true;
        }
        trace.push(LossTracePoint {
            time: arrivals[w],
            received: i + 1,
            recovered: mask.iter().filter(|&&b| b).count(),
            loss: part.loss_from_gram(gram, &mask),
        });
    }
    trace
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

fn main() {
    let mut h = Harness::new();
    println!("uepmm bench harness — one bench per paper figure pipeline + hot paths\n");

    // ---------------- L3 hot paths ------------------------------------
    let mut rng = Pcg64::seed_from(1);
    let spec_rxc = SyntheticSpec::fig9_rxc().scaled(6);
    let spec_cxr = SyntheticSpec::fig9_cxr().scaled(6);
    let cm = spec_rxc.class_map();
    let (a, b) = spec_rxc.sample_matrices(&mut rng);
    let gram = spec_rxc.part.gram(&spec_rxc.part.true_products(&a, &b));
    let ew = CodeSpec::stacked(CodeKind::EwUep(spec_rxc.gamma.clone()));
    let now_r1 =
        CodeSpec::new(CodeKind::NowUep(spec_rxc.gamma.clone()), EncodeStyle::RankOne);

    {
        let mut r = rng.split();
        h.bench("hot/encode: 30 EW-UEP packets (stacked)", || {
            let pkts = ew.generate_packets(&spec_rxc.part, &cm, 30, &mut r);
            std::hint::black_box(&pkts);
        });
    }
    {
        let mut r = rng.split();
        let pkts = ew.generate_packets(&spec_rxc.part, &cm, 30, &mut r);
        let space = UnknownSpace::for_code(&spec_rxc.part, EncodeStyle::Stacked);
        h.bench("hot/decode: absorb 30 stacked packets (RREF)", || {
            let mut st = DecodeState::new(space.clone());
            for p in &pkts {
                st.add_packet(p, None);
            }
            std::hint::black_box(st.num_recovered());
        });
    }
    {
        let mut r = rng.split();
        let pkts = now_r1.generate_packets(&spec_cxr.part, &spec_cxr.class_map(), 30, &mut r);
        let space = UnknownSpace::for_code(&spec_cxr.part, EncodeStyle::RankOne);
        h.bench("hot/decode: absorb 30 rank-one cxr packets (81 unk)", || {
            let mut st = DecodeState::new(space.clone());
            for p in &pkts {
                st.add_packet(p, None);
            }
            std::hint::black_box(st.num_recovered());
        });
    }
    {
        let mask = vec![false; 9];
        h.bench("hot/loss_from_gram (9 blocks)", || {
            std::hint::black_box(spec_rxc.part.loss_from_gram(&gram, &mask));
        });
    }
    {
        let sim = StragglerSim::new(30, LatencyModel::exp(1.0), 0.3);
        let mut r = rng.split();
        h.bench("hot/straggler arrivals (30 workers)", || {
            std::hint::black_box(sim.sample_arrivals(&mut r));
        });
    }

    // ---------------- sweep hot path: incremental vs pre-refactor ------
    {
        // one r×c trial (9 unknowns, diagonal Gram)
        let mut r = rng.split();
        let pkts = ew.generate_packets(&spec_rxc.part, &cm, 30, &mut r);
        let arrivals: Vec<f64> = (0..30).map(|i| (i as f64 * 0.37) % 2.0).collect();
        let mut scratch = SweepScratch::new();
        h.bench("sweep/loss_trace rxc 30pkts incremental+scratch", || {
            let trace = loss_trace_packets_scratch(
                &spec_rxc.part, &ew, &gram, &pkts, &arrivals, &mut scratch,
            );
            std::hint::black_box(trace.last().map(|p| p.loss));
        });
        h.bench("sweep/loss_trace rxc 30pkts reference (pre-refactor)", || {
            let trace =
                loss_trace_reference(&spec_rxc.part, &ew, &gram, &pkts, &arrivals);
            std::hint::black_box(trace.last().map(|p| p.loss));
        });
        // one c×r rank-one trial (81 unknowns incl. ghosts, dense Gram) —
        // the case where flat-row elimination and O(k) loss deltas pay most
        let cm_cxr = spec_cxr.class_map();
        let gram_cxr = {
            let mut r2 = rng.split();
            let (a2, b2) = spec_cxr.sample_matrices(&mut r2);
            spec_cxr.part.gram(&spec_cxr.part.true_products(&a2, &b2))
        };
        let pkts_r1 = now_r1.generate_packets(&spec_cxr.part, &cm_cxr, 30, &mut r);
        let mut scratch_r1 = SweepScratch::new();
        h.bench("sweep/loss_trace cxr-rank1 30pkts incremental+scratch", || {
            let trace = loss_trace_packets_scratch(
                &spec_cxr.part, &now_r1, &gram_cxr, &pkts_r1, &arrivals, &mut scratch_r1,
            );
            std::hint::black_box(trace.last().map(|p| p.loss));
        });
        h.bench("sweep/loss_trace cxr-rank1 30pkts reference (pre-refactor)", || {
            let trace = loss_trace_reference(
                &spec_cxr.part, &now_r1, &gram_cxr, &pkts_r1, &arrivals,
            );
            std::hint::black_box(trace.last().map(|p| p.loss));
        });
    }
    {
        // fig9-style Monte-Carlo sweep throughput: full mc_loss_vs_time
        // unit at 1 thread and at all cores (the tentpole's ≥5× target
        // reads off sweep/mc… (pre-refactor 1t) vs sweep/mc… (Nt))
        let spec = SyntheticSpec::fig9_rxc().scaled(15);
        let code = CodeSpec::stacked(CodeKind::EwUep(spec.gamma.clone()));
        let ts = [0.5, 1.0, 1.5];
        h.bench("sweep/mc_loss_vs_time 2x100 trials (1 thread)", || {
            std::hint::black_box(mc_loss_vs_time(&spec, &code, &ts, 2, 100, 3, 1));
        });
        let cores = available_parallelism();
        h.bench(
            &format!("sweep/mc_loss_vs_time 2x100 trials ({cores} threads)"),
            || {
                std::hint::black_box(mc_loss_vs_time(&spec, &code, &ts, 2, 100, 3, cores));
            },
        );
    }

    // ---------------- cluster runtime hot paths ------------------------
    {
        use uepmm::cluster::wire::{self, Msg, ResultMsg};
        use uepmm::cluster::{CacheKey, EncodedBlockCache};
        use uepmm::coordinator::EncodedA;

        // wire codec: one result frame at the fig9/6 payload size
        let mut r = rng.split();
        let payload = Matrix::randn(50, 50, 0.0, 1.0, &mut r);
        let msg = Msg::Result(ResultMsg {
            request_id: 1,
            slot: 0,
            attempt: 0,
            delay: 0.5,
            compute_secs: 0.0,
            payload,
        });
        h.bench("cluster/wire: encode+decode 50x50 result frame", || {
            let bytes = wire::encode(&msg).unwrap();
            std::hint::black_box(wire::decode_frame(&bytes).unwrap());
        });

        // rateless family: seeded coefficient derivation, one packet
        // payload, a full stream decode to rank K, and the per-packet
        // result frame codec
        use uepmm::cluster::wire::RatelessResultMsg;
        use uepmm::coding::RatelessSpec;
        use uepmm::coordinator::RatelessPlan;
        let spec_rl = SyntheticSpec::fig9_rxc().scaled(10).with_blocks(6);
        let mut r2 = rng.split();
        let (ra, rb) = spec_rl.sample_matrices(&mut r2);
        let rplan = RatelessPlan::build_with_classes(
            &spec_rl.part,
            RatelessSpec::new(0.05, 0.1, spec_rl.gamma.clone()),
            spec_rl.class_map(),
            &ra,
            &rb,
        )
        .unwrap();
        h.bench("cluster/rateless-encode: derive 36 seeded packets", || {
            for k in 0..36u32 {
                std::hint::black_box(rplan.packet(1, 0, k));
            }
        });
        let pkt0 = rplan.packet(1, 0, 0);
        h.bench("cluster/rateless-encode: one packet payload (K=36)", || {
            std::hint::black_box(rplan.payload(&pkt0));
        });
        h.bench("cluster/rateless-decode: absorb one stream to rank 36", || {
            let mut st = DecodeState::new(rplan.space.clone());
            let mut k = 0u32;
            while !st.is_complete() && k < 200 {
                let p = rplan.packet(1, 0, k);
                st.add_packet(&p, None);
                k += 1;
            }
            std::hint::black_box(st.num_recovered());
        });
        let rmsg = Msg::RatelessResult(RatelessResultMsg {
            request_id: 1,
            stream: 0,
            seq: 0,
            attempt: 0,
            delay: 0.5,
            compute_secs: 0.0,
            more: true,
            payload: rplan.payload(&pkt0),
        });
        h.bench("cluster/wire: encode+decode rateless result frame", || {
            let bytes = wire::encode(&rmsg).unwrap();
            std::hint::black_box(wire::decode_frame(&bytes).unwrap());
        });

        // encoded-block cache: the per-request A-side cost a miss pays
        // (split + packet draw + every W_A) vs the hit's lookup
        let (a2, _) = spec_rxc.sample_matrices(&mut r);
        h.bench("cluster/encode-cache miss: EncodedA::encode 30 pkts", || {
            let mut rr = Pcg64::seed_from(5);
            std::hint::black_box(
                EncodedA::encode(&spec_rxc.part, ew.clone(), &cm, 30, &a2, &mut rr)
                    .unwrap(),
            );
        });
        let mut cache = EncodedBlockCache::new(4);
        let key = CacheKey::new(0, 0, &spec_rxc.part, &ew, &cm, 30);
        let mut rr = Pcg64::seed_from(5);
        cache
            .get_or_insert_with(key.clone(), || {
                EncodedA::encode(&spec_rxc.part, ew.clone(), &cm, 30, &a2, &mut rr)
            })
            .unwrap();
        h.bench("cluster/encode-cache hit: lookup", || {
            let (enc, hit) = cache
                .get_or_insert_with(key.clone(), || unreachable!("cached"))
                .unwrap();
            assert!(hit);
            std::hint::black_box(enc.workers());
        });

        // Freivalds result verification: the O(n²) per-result probe the
        // coordinator runs on every arrival vs the O(n³) recompute it
        // replaces (the gap is the price of turning verification on)
        use std::sync::Arc;
        use uepmm::coordinator::Verifier;
        use uepmm::linalg::matmul;
        let wa = Matrix::randn(50, 30, 0.0, 1.0, &mut r);
        let wb = Matrix::randn(30, 50, 0.0, 1.0, &mut r);
        let honest = matmul(&wa, &wb);
        let jobs = vec![(Arc::new(wa.clone()), Arc::new(wb.clone()))];
        let mut vr = Pcg64::seed_from(17);
        let verifier = Verifier::new(&jobs, &mut vr);
        h.bench("cluster/verify: Freivalds check 50x30x50 result", || {
            assert!(verifier.check(0, &honest));
        });
        h.bench("cluster/verify: full recompute 50x30x50 (reference)", || {
            let exact = matmul(&wa, &wb);
            std::hint::black_box(honest.allclose(&exact, 1e-9));
        });
    }

    // ---------------- unified client API (Session / Backend) -----------
    {
        // batched vs sequential submission of a 32-request repeated-A
        // stream: every request after the first hits the session's
        // encoded-block cache; the cache-off row shows what each
        // request would pay without it
        use uepmm::api::{InProcessBackend, Request, Session};
        let spec_api = SyntheticSpec::fig9_rxc().scaled(10);
        let ew_api = CodeSpec::stacked(CodeKind::EwUep(spec_api.gamma.clone()));
        let cm_api = spec_api.class_map();
        let mut mats = Pcg64::seed_from(71);
        let a_mat = spec_api.sample_a(&mut mats);
        let bs: Vec<Matrix> = (0..32).map(|_| spec_api.sample_b(&mut mats)).collect();
        let mk_session = |cache: usize| {
            Session::builder()
                .partitioning(spec_api.part.clone())
                .code(ew_api.clone())
                .classes(cm_api.clone())
                .workers(spec_api.workers)
                .latency(LatencyModel::exp(1.0))
                .deadline(1.0)
                .cache_capacity(cache)
                .seed(9)
                .backend(InProcessBackend::serial())
                .build()
                .unwrap()
        };
        h.bench("api/batched 32-req repeated-A stream (encode cache)", || {
            let mut s = mk_session(8);
            let reqs: Vec<Request> = bs
                .iter()
                .map(|b| Request::new(0, a_mat.clone(), b.clone()))
                .collect();
            let handles = s.submit_batch(reqs).unwrap();
            let mut recovered = 0usize;
            for hd in handles {
                recovered += s.wait(hd).unwrap().outcome.recovered;
            }
            std::hint::black_box(recovered);
        });
        h.bench("api/sequential 32-req repeated-A stream (encode cache)", || {
            let mut s = mk_session(8);
            let mut recovered = 0usize;
            for b in &bs {
                recovered +=
                    s.run(Request::new(0, a_mat.clone(), b.clone())).unwrap().outcome.recovered;
            }
            std::hint::black_box(recovered);
        });
        h.bench("api/sequential 32-req repeated-A stream (cache off)", || {
            let mut s = mk_session(0);
            let mut recovered = 0usize;
            for b in &bs {
                recovered +=
                    s.run(Request::new(0, a_mat.clone(), b.clone())).unwrap().outcome.recovered;
            }
            std::hint::black_box(recovered);
        });
    }

    // ---------------- multi-tenant serve plane --------------------------
    if h.wants("service/served-request p50 (3 tenants, shared fleet)") {
        // three concurrent tenants stream repeated-A requests through one
        // loopback ServePlane over a 3-worker fleet; the recorded points
        // are the p50/p99 of every served request's client-observed wall
        // time — the PR-8 headline the CI regression gate watches
        use std::thread;
        use uepmm::api::{ClusterBackend, Request, Session};
        use uepmm::cluster::{
            spawn_loopback_workers, Connection, LoopbackTransport, ServePlane,
            ServiceConfig, WorkerConfig,
        };
        use uepmm::coding::WindowPolynomial;
        use uepmm::partition::{default_pair_classes, ClassMap};

        const TENANTS: usize = 3;
        const REQUESTS: usize = 8;
        let part_srv = Partitioning::rxc(3, 3, 4, 5, 4);
        let cm_srv = ClassMap::from_levels(
            &part_srv,
            vec![0, 1, 2],
            vec![0, 1, 2],
            &default_pair_classes(3),
        );
        let code_srv =
            CodeSpec::stacked(CodeKind::EwUep(WindowPolynomial::paper_table3()));

        let (mut transport, dialer) = LoopbackTransport::new();
        let plane = thread::spawn(move || {
            ServePlane::new(ServiceConfig::default()).run(&mut transport, TENANTS)
        });
        let workers = spawn_loopback_workers(&dialer, 3, &WorkerConfig::default());
        let handles: Vec<_> = (0..TENANTS)
            .map(|i| {
                let dialer = dialer.clone();
                let part = part_srv.clone();
                let cm = cm_srv.clone();
                let code = code_srv.clone();
                thread::spawn(move || {
                    let name = format!("bench-{i}");
                    let conn: Box<dyn Connection> =
                        Box::new(dialer.dial(&name).unwrap());
                    let backend =
                        ClusterBackend::connect_over(conn, &name).unwrap();
                    let mut s = Session::builder()
                        .partitioning(part)
                        .code(code)
                        .classes(cm)
                        .workers(14)
                        .latency(LatencyModel::exp(1.0))
                        .deadline(50.0)
                        .seed(900 + i as u64)
                        .backend(backend)
                        .build()
                        .unwrap();
                    let mut mats = Pcg64::with_stream(900 + i as u64, 1);
                    let a_t = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
                    let mut walls = Vec::with_capacity(REQUESTS);
                    for _ in 0..REQUESTS {
                        let b_t = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);
                        walls.push(
                            s.run(Request::new(0, a_t.clone(), b_t)).unwrap().wall,
                        );
                    }
                    s.shutdown().unwrap();
                    walls
                })
            })
            .collect();
        let mut walls: Vec<Duration> = handles
            .into_iter()
            .flat_map(|jh| jh.join().unwrap())
            .collect();
        plane.join().unwrap();
        for jh in workers {
            jh.join().unwrap().unwrap();
        }
        walls.sort();
        let pct = |q: f64| walls[((walls.len() - 1) as f64 * q).round() as usize];
        h.record(
            "service/served-request p50 (3 tenants, shared fleet)",
            pct(0.5),
            walls.len(),
        );
        h.record(
            "service/served-request p99 (3 tenants, shared fleet)",
            pct(0.99),
            walls.len(),
        );
    }

    // ---------------- matmul tiers (native engine) ---------------------
    for &(m, k, n) in &[(64usize, 288usize, 64usize), (300, 900, 300)] {
        let a = Matrix::randn(m, k, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 0.0, 1.0, &mut rng);
        if m * k * n <= 64 * 288 * 64 {
            h.bench(&format!("matmul/naive {m}x{k}x{n}"), || {
                std::hint::black_box(matmul_naive(&a, &b));
            });
        }
        h.bench(&format!("matmul/blocked-1t {m}x{k}x{n}"), || {
            std::hint::black_box(matmul_with(
                &a,
                &b,
                MatmulOpts { threads: 1, naive_below: 0, ..Default::default() },
            ));
        });
        h.bench(&format!("matmul/parallel {m}x{k}x{n}"), || {
            std::hint::black_box(matmul_with(
                &a,
                &b,
                MatmulOpts { naive_below: 0, ..Default::default() },
            ));
        });
    }

    // ---------------- worker job + coordinator end-to-end --------------
    {
        let mut r = rng.split();
        let plan = Plan::build_with_classes(
            &spec_rxc.part,
            ew.clone(),
            cm.clone(),
            15,
            &a,
            &b,
            &mut r,
        )
        .unwrap();
        let engine = NativeEngine::default();
        h.bench("job/build+execute one stacked worker product", || {
            let (wa, wb) = build_job_matrices(
                &plan.part,
                &plan.a_blocks,
                &plan.b_blocks,
                &plan.packets[0].recipe,
            );
            std::hint::black_box(engine.matmul(&wa, &wb).unwrap());
        });
        let coord = Coordinator::new(NativeEngine::default());
        let arrivals: Vec<f64> = (0..15).map(|i| i as f64 * 0.1).collect();
        h.bench("coordinator/run 15 workers to T_max (native)", || {
            std::hint::black_box(coord.run(&plan, &arrivals, 0.8).unwrap());
        });
    }

    // ---------------- PJRT artifact execution (L1/L2 path) -------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let engine = PjrtEngine::from_artifacts("artifacts").unwrap();
        let qa = Matrix::randn(64, 96, 0.0, 1.0, &mut rng);
        let qb = Matrix::randn(96, 64, 0.0, 1.0, &mut rng);
        // compile once outside the timer
        engine.matmul(&qa, &qb).unwrap();
        h.bench("pjrt/block_matmul 64x96x64 (compiled Pallas)", || {
            std::hint::black_box(engine.matmul(&qa, &qb).unwrap());
        });
        let native = NativeEngine::default();
        h.bench("pjrt-vs-native/native 64x96x64", || {
            std::hint::black_box(native.matmul(&qa, &qb).unwrap());
        });
    } else {
        println!("(skipping pjrt benches — run `make artifacts`)");
    }

    // ---------------- per-figure pipelines -----------------------------
    {
        // Fig. 8: full analytic sweep (NOW + EW, 3 classes, N = 0..30)
        let gamma = [0.4, 0.35, 0.25];
        let k = [3usize, 3, 3];
        h.bench("fig8/analytic decode-prob sweep (N=0..30)", || {
            let mut acc = 0.0;
            for n in 0..=30usize {
                for l in 0..3 {
                    acc += uepmm::analysis::now_decode_prob(n, &gamma, &k, l);
                    acc += uepmm::analysis::ew_decode_prob(n, &gamma, &k, l);
                }
            }
            std::hint::black_box(acc);
        });
    }
    {
        // Fig. 9/10/11 unit of work: one Monte-Carlo trial
        let mut r = rng.split();
        let sim = StragglerSim::new(30, spec_rxc.latency.clone(), spec_rxc.omega());
        h.bench("fig9-11/one MC trial (packets+arrivals+decode+loss)", || {
            let pkts = ew.generate_packets(&spec_rxc.part, &cm, 30, &mut r);
            let arrivals = sim.sample_arrivals(&mut r);
            let trace = uepmm::sim::loss_trace_packets(
                &spec_rxc.part,
                &ew,
                &gram,
                &pkts,
                &arrivals,
            );
            std::hint::black_box(uepmm::sim::loss_at(&trace, 1.0));
        });
        let ts41: Vec<f64> = (0..41).map(|i| i as f64 / 20.0).collect();
        h.bench("fig9/analytic Theorem-2 curve (41 points)", || {
            let th = spec_rxc.theorem();
            std::hint::black_box(
                th.normalized_loss_curve(uepmm::analysis::UepStrategy::Now, &ts41),
            );
        });
    }
    {
        // Figs. 13-15 unit of work: one coded MLP training step
        let mut r = Pcg64::seed_from(33);
        let train = synthetic_digits(128, 11, &mut r);
        let mut mlp = Mlp::mnist(&mut r);
        let idx: Vec<usize> = (0..64).collect();
        let (x, y) = train.batch(&idx);
        let tau = TauSchedule::paper(3);
        for (name, paradigm, blocks) in [
            ("fig13/coded MLP step (rxc)", Paradigm::RowTimesCol, 3usize),
            ("fig14/coded MLP step (cxr)", Paradigm::ColTimesRow, 9),
        ] {
            let mut engine = DistributedMatmul::new(
                MatmulStrategy::Coded(CodedMatmulCfg {
                    paradigm,
                    blocks,
                    spec: CodeSpec::stacked(CodeKind::EwUep(
                        spec_rxc.gamma.clone(),
                    )),
                    workers: 15,
                    latency: LatencyModel::exp(0.5),
                    auto_omega: true,
                    t_max: 1.0,
                    s_levels: 3,
                }),
                Pcg64::seed_from(5),
            );
            h.bench(name, || {
                std::hint::black_box(mlp.train_step(&x, &y, 0.05, &mut engine, &tau, 0));
            });
        }
        let mut exact = DistributedMatmul::new(MatmulStrategy::Exact, Pcg64::seed_from(6));
        h.bench("fig13/centralized MLP step (reference)", || {
            std::hint::black_box(mlp.train_step(&x, &y, 0.05, &mut exact, &tau, 0));
        });
    }
    {
        // Fig. 5 / Table II unit of work: Gaussian fit of a gradient
        let mut r = rng.split();
        let g = Matrix::randn(784, 100, 0.0, 1e-3, &mut r);
        h.bench("fig5/gaussian fit 784x100 gradient", || {
            std::hint::black_box(uepmm::util::stats::gaussian_fit_dense(g.data(), 1e-5));
        });
    }
    {
        // Fig. 1 unit of work: one coded CNN step at the small arch
        use uepmm::data::synthetic_cifar;
        use uepmm::nn::{Cnn, CnnArch};
        let mut r = Pcg64::seed_from(44);
        let arch = CnnArch::small();
        let train = synthetic_cifar(64, arch.side, 3, &mut r);
        let mut cnn = Cnn::init(arch, &mut r);
        let idx: Vec<usize> = (0..16).collect();
        let (x, y) = train.batch(&idx);
        let tau = TauSchedule::paper(3);
        let mut engine = DistributedMatmul::new(
            MatmulStrategy::Coded(CodedMatmulCfg {
                paradigm: Paradigm::RowTimesCol,
                blocks: 3,
                spec: CodeSpec::stacked(CodeKind::EwUep(spec_rxc.gamma.clone())),
                workers: 15,
                latency: LatencyModel::exp(0.5),
                auto_omega: true,
                t_max: 1.0,
                s_levels: 3,
            }),
            Pcg64::seed_from(7),
        );
        h.bench("fig1/coded CNN step (small arch)", || {
            std::hint::black_box(cnn.train_step(&x, &y, 0.1, &mut engine, &tau, 0, false));
        });
    }
    {
        // ablation sweep unit: full mc_loss_vs_time point
        h.bench("ablation/mc_loss_vs_time (1 inst x 20 trials x 3 ts)", || {
            let spec = SyntheticSpec::fig9_rxc().scaled(15);
            let code = CodeSpec::stacked(CodeKind::NowUep(spec.gamma.clone()));
            std::hint::black_box(mc_loss_vs_time(&spec, &code, &[0.5, 1.0, 1.5], 1, 20, 3, 1));
        });
    }

    h.write_csv();
    h.write_json();
}
