//! Property tests of the heterogeneity-aware work planner
//! (`coordinator::Assignment`) — the invariants `ClusterConfig::
//! hetero_assign` dispatch relies on, checked over randomized fleets
//! and window maps:
//!
//! * **work conservation** — every slot is planned exactly once, and
//!   per-worker counts sum to the slot count;
//! * **monotonicity** — a strictly slower worker never receives more
//!   slots than a faster one;
//! * **degenerate fleets** — all-equal scales reproduce least-
//!   outstanding round-robin exactly; a single usable survivor takes
//!   everything;
//! * **determinism** — bit-identical plans across reruns and across
//!   concurrent planning threads.

use std::collections::BTreeMap;

use uepmm::coordinator::Assignment;
use uepmm::rng::Pcg64;

/// A randomized fleet: ids are sparse and unsorted, scales span two
/// orders of magnitude.
fn random_fleet(rng: &mut Pcg64, n: usize) -> Vec<(u64, f64)> {
    let mut fleet: Vec<(u64, f64)> = (0..n)
        .map(|i| {
            let id = 1 + (rng.next_u64() % 50) + 50 * i as u64;
            let scale = 0.1 * (1.0 + (rng.next_u64() % 200) as f64);
            (id, scale)
        })
        .collect();
    // shuffle so the planner cannot rely on caller ordering
    for i in (1..fleet.len()).rev() {
        let j = (rng.next_u64() % (i as u64 + 1)) as usize;
        fleet.swap(i, j);
    }
    fleet
}

fn random_windows(rng: &mut Pcg64, slots: usize, classes: usize) -> Vec<usize> {
    (0..slots).map(|_| (rng.next_u64() % classes as u64) as usize).collect()
}

#[test]
fn every_slot_planned_exactly_once() {
    let mut rng = Pcg64::seed_from(11);
    for case in 0..50 {
        let slots = 1 + (rng.next_u64() % 40) as usize;
        let fleet = random_fleet(&mut rng, 1 + (rng.next_u64() % 8) as usize);
        let windows = random_windows(&mut rng, slots, 3);
        let a = Assignment::plan(&windows, &fleet)
            .unwrap_or_else(|| panic!("case {case}: usable fleet rejected"));
        assert_eq!(a.len(), slots);
        // dispatch order covers each slot once
        let mut seen = vec![false; slots];
        for &(slot, worker) in a.dispatch_order() {
            assert!(!seen[slot as usize], "case {case}: slot {slot} twice");
            seen[slot as usize] = true;
            // per-slot lookup agrees with the dispatch pairing
            assert_eq!(a.worker_of(slot as usize), worker, "case {case}");
        }
        assert!(seen.iter().all(|&s| s), "case {case}: slot unplanned");
        // counts are consistent with the dispatch list
        let mut tally: BTreeMap<u64, usize> = BTreeMap::new();
        for &(_, w) in a.dispatch_order() {
            *tally.entry(w).or_insert(0) += 1;
        }
        assert_eq!(a.counts().values().sum::<usize>(), slots, "case {case}");
        for (id, n) in a.counts() {
            assert_eq!(tally.get(id).copied().unwrap_or(0), *n, "case {case}");
        }
    }
}

#[test]
fn slower_workers_never_get_more_work() {
    let mut rng = Pcg64::seed_from(13);
    for case in 0..50 {
        let slots = 1 + (rng.next_u64() % 60) as usize;
        let fleet = random_fleet(&mut rng, 2 + (rng.next_u64() % 7) as usize);
        let windows = random_windows(&mut rng, slots, 4);
        let a = Assignment::plan(&windows, &fleet).unwrap();
        for &(i, si) in &fleet {
            for &(j, sj) in &fleet {
                if si < sj {
                    assert!(
                        a.counts()[&i] >= a.counts()[&j],
                        "case {case}: worker {i} (scale {si}) got \
                         {} slots, strictly slower {j} (scale {sj}) got {}",
                        a.counts()[&i],
                        a.counts()[&j],
                    );
                }
            }
        }
    }
}

/// Least-outstanding dispatch simulated over an id-ordered fleet: each
/// slot (in dispatch order) to the worker with the fewest assigned
/// jobs, ties to the lower id — what `ClusterServer` does without a
/// plan, minus failover.
fn least_outstanding(ids: &[u64], slots: usize) -> Vec<u64> {
    let mut sorted = ids.to_vec();
    sorted.sort_unstable();
    let mut load = vec![0usize; sorted.len()];
    (0..slots)
        .map(|_| {
            let best = (0..sorted.len())
                .min_by_key(|&w| (load[w], sorted[w]))
                .unwrap();
            load[best] += 1;
            sorted[best]
        })
        .collect()
}

#[test]
fn equal_scales_match_least_outstanding_dispatch() {
    let mut rng = Pcg64::seed_from(17);
    for case in 0..30 {
        let slots = 1 + (rng.next_u64() % 40) as usize;
        let n = 1 + (rng.next_u64() % 8) as usize;
        let mut fleet = random_fleet(&mut rng, n);
        for f in fleet.iter_mut() {
            f.1 = 1.0;
        }
        let windows = random_windows(&mut rng, slots, 3);
        let a = Assignment::plan(&windows, &fleet).unwrap();
        let ids: Vec<u64> = fleet.iter().map(|&(id, _)| id).collect();
        let expect = least_outstanding(&ids, slots);
        let got: Vec<u64> = a.dispatch_order().iter().map(|&(_, w)| w).collect();
        assert_eq!(got, expect, "case {case}: homogeneous plan must be \
                    least-outstanding round-robin");
    }
}

#[test]
fn single_survivor_takes_everything() {
    let windows = [2usize, 0, 1, 1, 0, 2];
    // every other worker has an unusable scale
    let fleet = [(9, f64::NAN), (4, 1.7), (2, 0.0), (8, -3.0), (1, f64::INFINITY)];
    let a = Assignment::plan(&windows, &fleet).unwrap();
    assert_eq!(a.counts()[&4], windows.len());
    assert!(a.dispatch_order().iter().all(|&(_, w)| w == 4));
    // nothing usable at all -> no plan (caller falls back)
    assert!(Assignment::plan(&windows, &[(9, f64::NAN), (2, 0.0)]).is_none());
}

#[test]
fn plans_are_bit_identical_across_reruns_and_threads() {
    let mut rng = Pcg64::seed_from(19);
    let fleet = random_fleet(&mut rng, 6);
    let windows = random_windows(&mut rng, 33, 3);
    let reference = Assignment::plan(&windows, &fleet).unwrap();
    // rerun in-thread
    for _ in 0..3 {
        assert_eq!(Assignment::plan(&windows, &fleet).unwrap(), reference);
    }
    // rerun concurrently: planning is pure, so parallelism cannot
    // perturb the plan
    for threads in [2usize, 4] {
        let plans: Vec<Assignment> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads)
                .map(|_| s.spawn(|| Assignment::plan(&windows, &fleet).unwrap()))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for p in plans {
            assert_eq!(p, reference, "threads={threads}");
        }
    }
}
