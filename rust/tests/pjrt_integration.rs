//! Integration: the three-layer AOT bridge.
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`
//! (L1 Pallas kernels inside L2 JAX graphs), compiles them on the PJRT
//! CPU client, and checks the numbers against the native Rust engine and
//! against hand-computed references. Skips (with a loud message) when
//! `artifacts/` has not been built — run `make artifacts` first.

use uepmm::coding::{CodeKind, CodeSpec, EncodeStyle, WindowPolynomial};
use uepmm::coordinator::{Coordinator, Plan};
use uepmm::linalg::{matmul, Matrix};
use uepmm::partition::Partitioning;
use uepmm::rng::Pcg64;
use uepmm::runtime::{ExecEngine, NativeEngine, PjrtEngine};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn pjrt_matmul_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::from_artifacts(&dir).expect("pjrt engine");
    assert_eq!(engine.name(), "pjrt");
    let mut rng = Pcg64::seed_from(1);
    // quickstart geometry shapes k = 1, 3, 9
    for k in [1usize, 3, 9] {
        let a = Matrix::randn(64, 32 * k, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(32 * k, 64, 0.0, 1.0, &mut rng);
        let got = engine.matmul(&a, &b).expect("pjrt matmul");
        let want = matmul(&a, &b);
        assert!(
            got.allclose(&want, 1e-3),
            "pjrt matmul k={k} mismatch: max diff {}",
            got.sub(&want).max_abs()
        );
    }
}

#[test]
fn pjrt_missing_shape_is_an_error() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::from_artifacts(&dir).expect("pjrt engine");
    let a = Matrix::zeros(7, 7);
    let b = Matrix::zeros(7, 7);
    assert!(engine.matmul(&a, &b).is_err());
}

#[test]
fn pjrt_uep_encode_artifact_matches_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::from_artifacts(&dir).expect("pjrt engine");
    let mut rng = Pcg64::seed_from(2);
    let coeffs = Matrix::randn(1, 3, 0.0, 1.0, &mut rng);
    let blocks: Vec<Matrix> =
        (0..3).map(|_| Matrix::randn(64, 32, 0.0, 1.0, &mut rng)).collect();
    // stack blocks into (3, 64, 32) row-major = concat of flats
    let mut flat = Vec::new();
    for b in &blocks {
        flat.extend_from_slice(b.data());
    }
    let stacked = Matrix::from_vec(3, 64 * 32, flat);
    // The runtime treats >1-D inputs as flat rows; pass via run() with
    // explicit shapes from the manifest.
    let outs = engine
        .run("uep_encode_3x64x32", &[&coeffs.transpose(), &stacked])
        .err();
    // shape validation must reject the wrong layout above
    assert!(outs.is_some());
}

#[test]
fn pjrt_worker_product_fused_artifact() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::from_artifacts(&dir).expect("pjrt engine");
    // Execute the fused rank-one job via the low-level f32 API (inputs
    // are 1-D/3-D, which the Matrix-level run() doesn't model).
    let exe = engine.executable("worker_product_64x32x64_k3").expect("compile");
    let mut rng = Pcg64::seed_from(3);
    let ca: Vec<f32> = (0..3).map(|_| rng.next_f32() - 0.5).collect();
    let cb: Vec<f32> = (0..3).map(|_| rng.next_f32() - 0.5).collect();
    let ablocks: Vec<Matrix> =
        (0..3).map(|_| Matrix::randn(64, 32, 0.0, 1.0, &mut rng)).collect();
    let bblocks: Vec<Matrix> =
        (0..3).map(|_| Matrix::randn(32, 64, 0.0, 1.0, &mut rng)).collect();
    let mut aflat: Vec<f32> = Vec::new();
    for m in &ablocks {
        aflat.extend(m.to_f32());
    }
    let mut bflat: Vec<f32> = Vec::new();
    for m in &bblocks {
        bflat.extend(m.to_f32());
    }
    let outs = exe
        .run_f32(&[
            (&ca, &[3][..]),
            (&aflat, &[3, 64, 32][..]),
            (&cb, &[3][..]),
            (&bflat, &[3, 32, 64][..]),
        ])
        .expect("execute fused job");
    assert_eq!(outs.len(), 1);
    let got = Matrix::from_f32(64, 64, &outs[0]);
    // reference: (Σ ca_i A_i)(Σ cb_j B_j)
    let mut wa = Matrix::zeros(64, 32);
    for (c, m) in ca.iter().zip(ablocks.iter()) {
        wa.axpy(*c as f64, m);
    }
    let mut wb = Matrix::zeros(32, 64);
    for (c, m) in cb.iter().zip(bblocks.iter()) {
        wb.axpy(*c as f64, m);
    }
    let want = matmul(&wa, &wb);
    assert!(
        got.allclose(&want, 1e-3),
        "fused worker product mismatch: {}",
        got.sub(&want).max_abs()
    );
}

#[test]
fn coordinator_on_pjrt_engine_end_to_end() {
    // The full L3-over-L2-over-L1 stack: coded multiplication with
    // worker payloads computed by the compiled Pallas artifacts.
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::from_artifacts(&dir).expect("pjrt engine");
    let mut rng = Pcg64::seed_from(4);
    let part = Partitioning::rxc(3, 3, 64, 32, 64);
    let sds = [10f64.sqrt(), 1.0, 0.1f64.sqrt()];
    let ab: Vec<Matrix> =
        sds.iter().map(|&s| Matrix::randn(64, 32, 0.0, s, &mut rng)).collect();
    let a = Matrix::vconcat(&ab.iter().collect::<Vec<_>>());
    let bb: Vec<Matrix> =
        sds.iter().map(|&s| Matrix::randn(32, 64, 0.0, s, &mut rng)).collect();
    let b = Matrix::hconcat(&bb.iter().collect::<Vec<_>>());
    let spec = CodeSpec::new(
        CodeKind::EwUep(WindowPolynomial::paper_table3()),
        EncodeStyle::Stacked,
    );
    let plan = Plan::build(&part, spec, 3, 15, &a, &b, &mut rng).unwrap();
    let arrivals: Vec<f64> = (0..15).map(|_| rng.next_f64()).collect();

    let pjrt_out = Coordinator::new(engine).run(&plan, &arrivals, 0.6).unwrap();
    let native_out =
        Coordinator::new(NativeEngine::default()).run(&plan, &arrivals, 0.6).unwrap();
    // identical packet sets + arrivals ⇒ identical recovery decisions,
    // and payloads agree to f32 precision
    assert_eq!(pjrt_out.received, native_out.received);
    assert_eq!(pjrt_out.recovered, native_out.recovered);
    assert!(
        (pjrt_out.normalized_loss - native_out.normalized_loss).abs() < 1e-3,
        "pjrt {} vs native {}",
        pjrt_out.normalized_loss,
        native_out.normalized_loss
    );
}
