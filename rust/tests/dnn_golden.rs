//! Golden-trace pin of the DNN-on-cluster headline scenario: a tiny
//! fixed-seed MLP trained with every matmul served by a loopback
//! [`ClusterBackend`] fleet under UEP coding and a virtual deadline.
//!
//! What is asserted, in order of strength:
//!
//! * **structural invariants** — every evaluation point has a finite
//!   loss, virtual time is strictly positive and non-decreasing, and
//!   the record's total virtual time bit-matches the last point's;
//! * **bit-identity** — the per-point `(train_loss, test_acc,
//!   virtual_time)` trace is bit-identical across reruns, across 2- vs
//!   4-thread fleets (injected per-slot delays, not wall clock, decide
//!   the decode), and with `hetero_assign` toggled on a homogeneous
//!   fleet (the plan may route slots to different workers, but with no
//!   injected multipliers every slot's delay — and therefore the
//!   decoded result — is unchanged);
//! * **golden fixture** — when `tests/golden/dnn_trace.txt` holds real
//!   bit patterns the trace must match them exactly; while the fixture
//!   is the `UNPINNED` sentinel the test prints the computed trace in
//!   fixture format for a maintainer to paste after one verified run.

use uepmm::api::{ClusterBackend, SharedBackend};
use uepmm::cluster::{ClusterConfig, DeadlineMode, WorkerConfig};
use uepmm::coding::{CodeKind, CodeSpec, EncodeStyle, WindowPolynomial};
use uepmm::data::synthetic_digits;
use uepmm::latency::LatencyModel;
use uepmm::nn::{
    train_mlp, ClusterMatmulCfg, CodedMatmulCfg, MatmulStrategy, Mlp,
    TauSchedule, TrainConfig, TrainRecord,
};
use uepmm::partition::Paradigm;
use uepmm::rng::Pcg64;

const FIXTURE: &str = include_str!("golden/dnn_trace.txt");

/// One evaluation point of the trace, fully bit-resolved.
type TracePoint = (usize, usize, u64, u64, u64);

fn trace_of(rec: &TrainRecord) -> Vec<TracePoint> {
    rec.points
        .iter()
        .map(|p| {
            (
                p.epoch,
                p.iter,
                p.train_loss.to_bits(),
                p.test_acc.to_bits(),
                p.virtual_time.to_bits(),
            )
        })
        .collect()
}

/// `None` while the fixture is the `UNPINNED` sentinel.
fn parse_fixture() -> Option<Vec<TracePoint>> {
    let lines: Vec<&str> = FIXTURE
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .collect();
    if lines.first() == Some(&"UNPINNED") {
        return None;
    }
    Some(
        lines
            .iter()
            .map(|l| {
                let f: Vec<&str> = l.split_whitespace().collect();
                assert_eq!(f.len(), 5, "malformed fixture line: {l}");
                (
                    f[0].parse().expect("epoch"),
                    f[1].parse().expect("iter"),
                    u64::from_str_radix(f[2], 16).expect("loss bits"),
                    u64::from_str_radix(f[3], 16).expect("acc bits"),
                    u64::from_str_radix(f[4], 16).expect("vt bits"),
                )
            })
            .collect(),
    )
}

/// Train the scenario MLP on a fresh loopback fleet and return the
/// record. Everything downstream of the two fixed seeds (model/data and
/// injected delays) is deterministic in virtual time.
fn run_trace(threads: usize, hetero: bool) -> TrainRecord {
    let backend = SharedBackend::new(
        ClusterBackend::loopback(
            threads,
            ClusterConfig {
                deadline: DeadlineMode::Virtual,
                time_scale: 0.0,
                cache_capacity: 0,
                hetero_assign: hetero,
                ..ClusterConfig::default()
            },
            WorkerConfig::default(),
            std::time::Duration::from_secs(30),
        )
        .expect("loopback fleet comes up"),
    );
    let strategy = MatmulStrategy::Cluster(ClusterMatmulCfg {
        coded: CodedMatmulCfg {
            paradigm: Paradigm::RowTimesCol,
            blocks: 3,
            spec: CodeSpec::new(
                CodeKind::EwUep(WindowPolynomial::paper_table3()),
                EncodeStyle::Stacked,
            ),
            workers: 12,
            latency: LatencyModel::exp(0.5),
            auto_omega: true,
            // tight enough that some rounds lose low-priority windows —
            // the UEP decode path, not just full recovery, is pinned
            t_max: 3.0,
            s_levels: 3,
        },
        backend: backend.clone(),
        adaptive: None,
        delay_seed: 0xded1_5eed,
        drift: None,
    });
    let mut rng = Pcg64::seed_from(41);
    let train = synthetic_digits(96, 11, &mut rng);
    let test = synthetic_digits(48, 13, &mut rng);
    let mut mlp = Mlp::new(&[784, 16, 10], &mut rng);
    let cfg = TrainConfig {
        lr: 0.05,
        epochs: 1,
        batch: 32,
        strategy,
        tau: TauSchedule::off(2),
        seed: 97,
        eval_every: 1,
        max_iters_per_epoch: 3,
    };
    let rec = train_mlp(&mut mlp, &train, &test, &cfg);
    backend.shutdown_inner().expect("loopback fleet shuts down");
    rec
}

#[test]
fn dnn_cluster_trace_is_golden() {
    let reference = run_trace(2, false);

    // -- structural invariants ------------------------------------------
    assert!(!reference.points.is_empty(), "no evaluation points");
    let mut prev_vt = 0.0;
    for p in &reference.points {
        assert!(p.train_loss.is_finite(), "non-finite loss at iter {}", p.iter);
        assert!(
            p.virtual_time > 0.0 && p.virtual_time >= prev_vt,
            "virtual time not monotone at iter {}: {} after {prev_vt}",
            p.iter,
            p.virtual_time,
        );
        prev_vt = p.virtual_time;
    }
    assert_eq!(
        reference.virtual_time.to_bits(),
        reference.points.last().unwrap().virtual_time.to_bits(),
        "record total must bit-match the last point"
    );
    assert!(
        reference.recovery_rate > 0.0 && reference.recovery_rate <= 1.0,
        "recovery rate {} out of range",
        reference.recovery_rate
    );

    // -- bit-identity across reruns, fleet sizes, hetero toggle ---------
    let ref_trace = trace_of(&reference);
    for (threads, hetero) in [(2usize, false), (4, false), (2, true)] {
        let other = run_trace(threads, hetero);
        assert_eq!(
            trace_of(&other),
            ref_trace,
            "trace diverged at threads={threads} hetero={hetero}"
        );
        assert_eq!(
            other.recovery_rate.to_bits(),
            reference.recovery_rate.to_bits(),
            "recovery rate diverged at threads={threads} hetero={hetero}"
        );
    }

    // -- golden fixture -------------------------------------------------
    match parse_fixture() {
        Some(golden) => assert_eq!(
            ref_trace, golden,
            "trace no longer matches tests/golden/dnn_trace.txt — if the \
             change is intentional, re-pin from the printout of an \
             UNPINNED run"
        ),
        None => {
            println!(
                "fixture is UNPINNED; paste the following into \
                 rust/tests/golden/dnn_trace.txt to pin:"
            );
            for (epoch, iter, loss, acc, vt) in &ref_trace {
                println!("{epoch} {iter} {loss:016x} {acc:016x} {vt:016x}");
            }
        }
    }
}
