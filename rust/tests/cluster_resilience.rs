//! Fault-injection integration tests for the resilient job lifecycle:
//! worker death mid-request (TCP), eviction followed by rejoin, and the
//! maintenance-interleaving regression — a request stream with
//! `maintain()` calls woven through it must report bit-identically to
//! one without, because no result frame is ever dropped.

use std::time::Duration;

use uepmm::api::{ClusterBackend, Request, RunReport, Session};
use uepmm::cluster::{
    run_worker, ClusterConfig, ClusterServer, Connection, DeadlineMode, Msg,
    ResultMsg, TcpConn, TcpTransport, Transport, WorkerConfig,
};
use uepmm::coding::{CodeKind, CodeSpec};
use uepmm::coordinator::Plan;
use uepmm::latency::LatencyModel;
use uepmm::linalg::{matmul, Matrix};
use uepmm::partition::{default_pair_classes, ClassMap, Partitioning};
use uepmm::rng::Pcg64;
use uepmm::runtime::NativeEngine;

fn spawn_tcp_worker(
    addr: String,
    name: &str,
) -> std::thread::JoinHandle<uepmm::cluster::WorkerStats> {
    let cfg = WorkerConfig { name: name.to_string(), ..Default::default() };
    std::thread::spawn(move || {
        let mut conn = TcpConn::connect(&addr).expect("worker connect");
        run_worker(&mut conn, &NativeEngine::serial(), &cfg).expect("worker loop")
    })
}

/// MDS keeps full-decode assertions seed-independent: any ≥ 9 received
/// packets recover all 9 sub-products.
fn mds_plan(workers: usize, seed: u64) -> Plan {
    let mut rng = Pcg64::seed_from(seed);
    let part = Partitioning::rxc(3, 3, 4, 5, 4);
    let a = Matrix::randn(12, 5, 0.0, 1.0, &mut rng);
    let b = Matrix::randn(5, 12, 0.0, 1.0, &mut rng);
    let spec = CodeSpec::stacked(CodeKind::Mds);
    Plan::build(&part, spec, 3, workers, &a, &b, &mut rng).unwrap()
}

/// Killing one of three workers mid-request must not lose its slots:
/// they re-dispatch onto the survivors and the MDS plan fully decodes.
#[test]
fn killing_a_tcp_worker_mid_request_redispatches_all_its_slots() {
    let mut transport = TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr = transport.local_addr();
    let w1 = spawn_tcp_worker(addr.clone(), "healthy-1");
    let w2 = spawn_tcp_worker(addr.clone(), "healthy-2");
    // the victim computes exactly one job honestly, then vanishes with
    // the rest of its backlog unanswered — at the socket level that is
    // what a SIGKILL'd worker process looks like to the coordinator
    let victim_addr = addr.clone();
    let victim = std::thread::spawn(move || {
        let mut conn = TcpConn::connect(&victim_addr).expect("victim connect");
        conn.send(&Msg::Hello { agent: "victim".to_string() }).unwrap();
        assert!(matches!(conn.recv().unwrap(), Msg::Welcome { .. }));
        let mut replied = false;
        loop {
            match conn.recv().unwrap() {
                Msg::Job(job) if !replied => {
                    let payload = matmul(&job.wa, &job.wb);
                    conn.send(&Msg::Result(ResultMsg {
                        request_id: job.request_id,
                        slot: job.slot,
                        attempt: job.attempt,
                        delay: job.injected_delay.unwrap_or(0.1),
                        compute_secs: 0.0,
                        payload,
                    }))
                    .unwrap();
                    replied = true;
                }
                Msg::Job(_) => break, // die holding the second job
                _ => {}
            }
        }
    });

    let mut server = ClusterServer::new(ClusterConfig::default());
    let joined = server
        .accept_workers(&mut transport, 3, Duration::from_secs(20))
        .unwrap();
    assert_eq!(joined, 3);

    let plan = mds_plan(12, 41);
    let delays = vec![0.1; 12];
    let out = server.serve_plan(&plan, 1.0, Some(&delays)).unwrap();
    victim.join().unwrap();

    // every slot stranded on the victim was re-dispatched and landed
    assert!(out.retries > 0, "victim's jobs must be re-dispatched: {out:?}");
    assert_eq!(out.missing(), 0, "no dispatched work may be lost: {out:?}");
    assert_eq!(out.outcome.received, 12);
    assert_eq!(out.outcome.recovered, 9, "the MDS plan must fully decode");
    assert!(out.outcome.normalized_loss < 1e-9);
    assert_eq!(server.live_workers(), 2);

    server.shutdown();
    assert!(w1.join().unwrap().clean_shutdown);
    assert!(w2.join().unwrap().clean_shutdown);
}

/// An agent whose connection died is evicted — and a fresh connection
/// re-registering under the same name revives its slot (same worker id)
/// and serves again.
#[test]
fn tcp_worker_rejoins_after_eviction_and_serves() {
    let mut transport = TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr = transport.local_addr();
    let stayer = spawn_tcp_worker(addr.clone(), "stayer");
    // first incarnation of "phoenix": registers, then drops dead
    let phoenix_addr = addr.clone();
    let phoenix1 = std::thread::spawn(move || {
        let mut conn = TcpConn::connect(&phoenix_addr).expect("phoenix connect");
        conn.send(&Msg::Hello { agent: "phoenix".to_string() }).unwrap();
        assert!(matches!(conn.recv().unwrap(), Msg::Welcome { .. }));
        drop(conn);
    });
    let mut server = ClusterServer::new(ClusterConfig::default());
    let joined = server
        .accept_workers(&mut transport, 2, Duration::from_secs(20))
        .unwrap();
    assert_eq!(joined, 2);
    phoenix1.join().unwrap();
    let phoenix_id = server
        .worker_info()
        .iter()
        .find(|w| w.name == "phoenix")
        .unwrap()
        .id;

    // serving discovers the dead connection; the stayer carries the load
    let plan = mds_plan(10, 43);
    let out = server.serve_plan(&plan, 1.0, Some(&vec![0.1; 10])).unwrap();
    assert_eq!(out.outcome.received, 10);
    assert_eq!(out.missing(), 0);
    assert_eq!(server.live_workers(), 1);

    // second incarnation dials in under the same name: the dead slot
    // revives in place instead of growing the registry
    let rejoin = spawn_tcp_worker(addr.clone(), "phoenix");
    let joined = server
        .accept_workers(&mut transport, 1, Duration::from_secs(20))
        .unwrap();
    assert_eq!(joined, 1);
    assert_eq!(server.live_workers(), 2);
    let info = server.worker_info();
    assert_eq!(info.len(), 2, "rejoin must not duplicate the slot");
    let phoenix = info.iter().find(|w| w.name == "phoenix").unwrap();
    assert_eq!(phoenix.id, phoenix_id);
    assert!(phoenix.alive);

    // … and the rejoined worker takes dispatched work again
    let plan = mds_plan(10, 44);
    let out = server.serve_plan(&plan, 1.0, Some(&vec![0.1; 10])).unwrap();
    assert_eq!(out.outcome.received, 10);
    assert_eq!(out.missing(), 0);
    let phoenix = server
        .worker_info()
        .into_iter()
        .find(|w| w.name == "phoenix")
        .unwrap();
    assert!(phoenix.jobs_done > 0, "rejoined worker must get work");

    server.shutdown();
    assert!(stayer.join().unwrap().clean_shutdown);
    assert!(rejoin.join().unwrap().clean_shutdown);
}

// ---------------------------------------------------------------------
// maintenance-interleaving regression

fn streamed_reports(maintain: bool) -> Vec<RunReport> {
    let backend = ClusterBackend::loopback(
        3,
        ClusterConfig {
            deadline: DeadlineMode::Virtual,
            time_scale: 0.0,
            cache_capacity: 0,
            ..ClusterConfig::default()
        },
        WorkerConfig::default(),
        Duration::from_secs(30),
    )
    .unwrap();
    let part = Partitioning::rxc(3, 3, 4, 5, 4);
    let pair = default_pair_classes(3);
    let cm = ClassMap::from_levels(&part, vec![0, 1, 2], vec![0, 1, 2], &pair);
    let mut session = Session::builder()
        .partitioning(part)
        .code(CodeSpec::stacked(CodeKind::Mds))
        .classes(cm)
        .workers(12)
        .latency(LatencyModel::exp(1.0))
        // tight enough that some arrivals are late: the late/received
        // split must also be invariant under maintenance
        .deadline(0.9)
        .score(true)
        .seed(5)
        .backend(backend)
        .build()
        .unwrap();
    let mut mats = Pcg64::with_stream(5, 1);
    let mut reports = Vec::new();
    for req in 0..4u64 {
        let a = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
        let b = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);
        let handle = session.submit(Request::new(req, a, b)).unwrap();
        if maintain {
            // heartbeat while the request is in flight: must not evict
            // anyone or swallow any frame
            let m = session.maintain().unwrap();
            assert!(m.evicted.is_empty(), "healthy pool evicted: {m:?}");
        }
        reports.push(session.wait(handle).unwrap());
        if maintain {
            session.maintain().unwrap();
        }
    }
    session.shutdown().unwrap();
    reports
}

/// Wall-mode result-drop regression through the public API: after a
/// tight-deadline request, the paced workers' results are still in
/// flight when `maintain()` runs its heartbeat. The heartbeat must
/// buffer every frame it reads while waiting for acks (proving the
/// workers alive), and the buffered backlog must not disturb the next
/// request's accounting.
#[test]
fn maintain_buffers_straggler_frames_between_wall_requests() {
    let backend = ClusterBackend::loopback(
        2,
        ClusterConfig {
            deadline: DeadlineMode::Wall,
            time_scale: 0.02,
            late_drain: Duration::from_millis(1),
            heartbeat_timeout: Duration::from_secs(5),
            cache_capacity: 0,
            ..ClusterConfig::default()
        },
        WorkerConfig {
            name: "paced".to_string(),
            // self-sampled pacing: 2.0 virtual × 0.02 = 40 ms per job
            latency: Some(LatencyModel::Deterministic { t: 2.0 }),
            time_scale: 0.02,
            ..WorkerConfig::default()
        },
        Duration::from_secs(30),
    )
    .unwrap();
    let part = Partitioning::rxc(3, 3, 4, 5, 4);
    let pair = default_pair_classes(3);
    let cm = ClassMap::from_levels(&part, vec![0, 1, 2], vec![0, 1, 2], &pair);
    let mut session = Session::builder()
        .partitioning(part)
        .code(CodeSpec::stacked(CodeKind::Mds))
        .classes(cm)
        .workers(12)
        // 10 ms wall deadline: every 40 ms-paced result is in flight
        // when the request returns
        .deadline(0.5)
        .score(true)
        .seed(9)
        .backend(backend)
        .build()
        .unwrap();
    let mut mats = Pcg64::with_stream(9, 1);
    let a = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
    let b = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);
    let first = session.run(Request::new(0, a.clone(), b)).unwrap();
    assert!(first.missing() > 0, "nothing can land in 10 ms: {first:?}");

    let m = session.maintain().unwrap();
    assert!(m.evicted.is_empty(), "paced workers are healthy: {m:?}");
    assert!(
        m.buffered_results > 0,
        "in-flight result frames must be buffered, not dropped: {m:?}"
    );

    // a generous follow-up request drains the stale backlog quietly and
    // decodes fully — the buffered frames poisoned nothing
    let b2 = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);
    let second = session.run(Request::new(0, a, b2).deadline(100.0)).unwrap();
    assert_eq!(second.outcome.recovered, 9, "{second:?}");
    assert_eq!(second.missing(), 0);
    session.shutdown().unwrap();
}

/// The result-drop regression: a stream with `maintain()` interleaved
/// (heartbeats racing the request pipeline) must produce bit-identical
/// reports to an undisturbed run — no frame dropped, no count shifted.
#[test]
fn maintain_interleaved_stream_reports_bit_identically() {
    let plain = streamed_reports(false);
    let maintained = streamed_reports(true);
    assert_eq!(plain.len(), maintained.len());
    for (i, (x, y)) in plain.iter().zip(&maintained).enumerate() {
        assert_eq!(x.outcome.received, y.outcome.received, "req {i}: received");
        assert_eq!(x.late, y.late, "req {i}: late");
        assert_eq!(x.dispatched, y.dispatched, "req {i}: dispatched");
        assert_eq!(x.retries, y.retries, "req {i}: retries");
        assert_eq!(x.corrupt, y.corrupt, "req {i}: corrupt");
        assert_eq!(
            x.outcome.recovered, y.outcome.recovered,
            "req {i}: recovered"
        );
        assert_eq!(
            x.outcome.c_hat.data(),
            y.outcome.c_hat.data(),
            "req {i}: c_hat bits"
        );
        assert_eq!(
            x.outcome.loss.to_bits(),
            y.outcome.loss.to_bits(),
            "req {i}: loss bits"
        );
        assert_eq!(
            x.progress.events(),
            y.progress.events(),
            "req {i}: progress stream"
        );
    }
}
