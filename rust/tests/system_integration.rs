//! System-level integration: full multi-module flows exercised together
//! (no PJRT required — see pjrt_integration.rs for the artifact path).
//! The deprecated `run_service` shim is exercised on purpose: its
//! contract (bit-compatibility with the virtual-time path) must hold
//! until the shim is removed. New-API flows live in api_backends.rs.
#![allow(deprecated)]

use uepmm::analysis::{now_decode_prob, TheoremLoss, UepStrategy};
use uepmm::coding::{CodeKind, CodeSpec, EncodeStyle};
use uepmm::config::SyntheticSpec;
use uepmm::coordinator::{run_service, Coordinator, Plan, ServiceConfig};
use uepmm::data::synthetic_digits;
use uepmm::experiments::mc_loss_vs_time;
use uepmm::latency::LatencyModel;
use uepmm::nn::{
    train_mlp, CodedMatmulCfg, MatmulStrategy, Mlp, TauSchedule, TrainConfig,
};
use uepmm::partition::Paradigm;
use uepmm::rng::Pcg64;
use uepmm::runtime::NativeEngine;
use uepmm::sim::StragglerSim;

/// Monte-Carlo simulation of the full coordinator stack must agree with
/// the Theorem 2 closed form on Assumption-1 matrices (r×c, stacked
/// NOW-UEP — the case where the analysis is exact).
#[test]
fn theorem2_matches_full_stack_simulation() {
    let spec = SyntheticSpec::fig9_rxc().scaled(12);
    let th: TheoremLoss = spec.theorem();
    let code = CodeSpec::new(CodeKind::NowUep(spec.gamma.clone()), EncodeStyle::Stacked);
    let ts = [0.4, 0.8, 1.4];
    let sim = mc_loss_vs_time(&spec, &code, &ts, 3, 250, 42, 4);
    for (i, &t) in ts.iter().enumerate() {
        let analytic = th.normalized_loss(UepStrategy::Now, t);
        assert!(
            (sim[i] - analytic).abs() < 0.06,
            "t={t}: sim {} vs Theorem 2 {analytic}",
            sim[i]
        );
    }
}

/// Coordinator + straggler sim: per-class recovery frequencies across
/// many runs must track the analytic decoding probabilities.
#[test]
fn per_class_recovery_tracks_analysis() {
    let spec = SyntheticSpec::fig9_rxc().scaled(25);
    let cm = spec.class_map();
    let code = CodeSpec::new(CodeKind::NowUep(spec.gamma.clone()), EncodeStyle::Stacked);
    let mut rng = Pcg64::seed_from(9);
    let (a, b) = spec.sample_matrices(&mut rng);
    let coord = Coordinator::new(NativeEngine::serial());
    let sim = StragglerSim::new(spec.workers, spec.latency.clone(), spec.omega());
    let t_max = 0.8;
    let trials = 150;
    let mut class_hits = vec![0usize; 3];
    let mut arrived = 0usize;
    for _ in 0..trials {
        let plan = Plan::build_with_classes(
            &spec.part, code.clone(), cm.clone(), spec.workers, &a, &b, &mut rng,
        )
        .unwrap();
        let arrivals = sim.sample_arrivals(&mut rng);
        let out = coord.run(&plan, &arrivals, t_max).unwrap();
        arrived += out.received;
        for l in 0..3 {
            if out.per_class_recovered[l] == cm.members[l].len() {
                class_hits[l] += 1;
            }
        }
    }
    // expected arrivals
    let f = spec.latency.cdf_scaled(t_max, spec.omega());
    let e_n = spec.workers as f64 * f;
    let emp_n = arrived as f64 / trials as f64;
    assert!((emp_n - e_n).abs() < 0.9, "arrivals {emp_n} vs {e_n}");
    // class-1 recovery frequency ≈ Σ_w P(w)·P_d1(w)
    let gamma = spec.gamma.probs().to_vec();
    let k = cm.class_sizes();
    let analytic: f64 = (0..=spec.workers)
        .map(|w| {
            uepmm::analysis::binomial_pmf(spec.workers, w, f)
                * now_decode_prob(w, &gamma, &k, 0)
        })
        .sum();
    let emp = class_hits[0] as f64 / trials as f64;
    assert!(
        (emp - analytic).abs() < 0.12,
        "class-1 recovery {emp} vs analytic {analytic}"
    );
}

/// The wall-clock service path and the virtual-time coordinator agree on
/// what a given arrival pattern decodes (same seed ⇒ same packets).
#[test]
fn service_and_virtual_paths_consistent() {
    let spec = SyntheticSpec::fig9_rxc().scaled(25);
    let mut rng = Pcg64::seed_from(31);
    let (a, b) = spec.sample_matrices(&mut rng);
    let code = CodeSpec::stacked(CodeKind::EwUep(spec.gamma.clone()));
    let plan = Plan::build_with_classes(
        &spec.part, code, spec.class_map(), 15, &a, &b, &mut rng,
    )
    .unwrap();
    // Wall-clock margins are generous (0.9 s deadline for 1.5 ms of
    // sleeps) so the test stays robust in debug builds on a loaded
    // single-core machine.
    let cfg = ServiceConfig {
        latency: LatencyModel::Deterministic { t: 0.1 },
        omega: 1.0,
        t_max: 60.0,
        time_scale: 0.015,
        threads: 4,
    };
    let mut srng = Pcg64::seed_from(77);
    let service = run_service(&plan, &cfg, &mut srng).unwrap();
    // deterministic latency 0.1 « deadline: everything arrives both ways
    let coord = Coordinator::new(NativeEngine::serial());
    let virtual_out = coord.run(&plan, &vec![0.1; 15], 60.0).unwrap();
    assert_eq!(service.outcome.received, virtual_out.received);
    assert_eq!(service.outcome.recovered, virtual_out.recovered);
    assert!(
        (service.outcome.normalized_loss - virtual_out.normalized_loss).abs() < 1e-9
    );
}

/// Distributed coded training end-to-end: a generous deadline matches
/// centralized training exactly (same seeds), because every sub-product
/// is recovered exactly.
#[test]
fn coded_training_with_full_recovery_equals_centralized() {
    let mut rng = Pcg64::seed_from(4);
    let train = synthetic_digits(256, 11, &mut rng);
    let test = synthetic_digits(64, 13, &mut rng);
    let mk_cfg = |strategy| TrainConfig {
        lr: 0.05,
        epochs: 1,
        batch: 32,
        strategy,
        tau: TauSchedule::paper(3),
        seed: 2,
        eval_every: 4,
        max_iters_per_epoch: 6,
    };
    let coded = MatmulStrategy::Coded(CodedMatmulCfg {
        paradigm: Paradigm::ColTimesRow,
        blocks: 9,
        spec: CodeSpec::stacked(CodeKind::Mds),
        workers: 12,
        latency: LatencyModel::exp(0.5),
        auto_omega: true,
        t_max: 1e9,
        s_levels: 3,
    });
    let mut rng_a = Pcg64::seed_from(8);
    let mut mlp_a = Mlp::new(&[784, 32, 16, 10], &mut rng_a);
    let mut rng_b = Pcg64::seed_from(8);
    let mut mlp_b = Mlp::new(&[784, 32, 16, 10], &mut rng_b);
    let rec_central = train_mlp(&mut mlp_a, &train, &test, &mk_cfg(MatmulStrategy::Exact));
    let rec_coded = train_mlp(&mut mlp_b, &train, &test, &mk_cfg(coded));
    assert!((rec_coded.recovery_rate - 1.0).abs() < 1e-12);
    for (pa, pb) in rec_central.points.iter().zip(rec_coded.points.iter()) {
        assert!(
            (pa.train_loss - pb.train_loss).abs() < 1e-9,
            "loss diverged: {} vs {}",
            pa.train_loss,
            pb.train_loss
        );
    }
    assert_eq!(rec_central.final_test_acc, rec_coded.final_test_acc);
}

/// CLI experiment registry covers every figure/table promised in
/// DESIGN.md §4.
#[test]
fn experiment_registry_is_complete() {
    let names: Vec<&str> = uepmm::experiments::registry()
        .into_iter()
        .map(|(n, _, _)| n)
        .collect();
    for expected in [
        "fig1", "fig5", "fig8", "fig9", "fig10", "fig11", "fig13", "fig14",
        "fig15", "params", "ablation-encoding", "ablation-gamma",
    ] {
        assert!(names.contains(&expected), "missing experiment {expected}");
    }
}
