//! End-to-end TCP cluster integration: a coordinator and two worker
//! agents on localhost, real sockets, wall-clock deadlines, and a
//! deliberate straggler.
//!
//! Timing margins are deliberately huge (the straggler is 50× slower
//! than the fast worker) so the assertions hold on a loaded CI box.

use std::time::Duration;

use uepmm::cluster::{
    run_worker, ClusterConfig, ClusterServer, CodingConfig, DeadlineMode,
    MatmulRequest, TcpConn, TcpTransport, WorkerConfig,
};
use uepmm::coding::{CodeKind, CodeSpec};
use uepmm::latency::LatencyModel;
use uepmm::linalg::Matrix;
use uepmm::partition::{default_pair_classes, ClassMap, Partitioning};
use uepmm::rng::Pcg64;
use uepmm::runtime::NativeEngine;

/// Wall seconds per virtual time unit in this test.
const TIME_SCALE: f64 = 0.05;

fn spawn_tcp_worker(
    addr: String,
    name: &str,
    delay: f64,
) -> std::thread::JoinHandle<uepmm::cluster::WorkerStats> {
    let cfg = WorkerConfig {
        name: name.to_string(),
        latency: Some(LatencyModel::Deterministic { t: delay }),
        omega: 1.0,
        time_scale: TIME_SCALE,
        seed: 0,
    };
    std::thread::spawn(move || {
        let mut conn = TcpConn::connect(&addr).expect("worker connect");
        run_worker(&mut conn, &NativeEngine::serial(), &cfg).expect("worker loop")
    })
}

/// The acceptance scenario: a request stream over TCP where the
/// straggler misses tight deadlines, with the decoded loss monotone
/// non-increasing as the deadline grows, cache hits on the repeated-`A`
/// stream, and a clean shutdown.
#[test]
fn tcp_cluster_deadline_sweep_with_straggler() {
    let mut transport = TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr = transport.local_addr();
    // fast worker: 0.04 virtual (2 ms wall) per job; straggler: 2.0
    // virtual (100 ms wall) per job
    let fast = spawn_tcp_worker(addr.clone(), "fast", 0.04);
    let slow = spawn_tcp_worker(addr.clone(), "slow", 2.0);

    let mut server = ClusterServer::new(ClusterConfig {
        deadline: DeadlineMode::Wall,
        time_scale: TIME_SCALE,
        late_drain: Duration::from_millis(20),
        ..ClusterConfig::default()
    });
    let joined = server
        .accept_workers(&mut transport, 2, Duration::from_secs(20))
        .unwrap();
    assert_eq!(joined, 2);

    // uncoded: every received packet recovers exactly one sub-product,
    // so recovery counts follow arrivals deterministically. With 9 jobs
    // round-robined over 2 workers, the straggler owns 4–5 sub-products:
    // a tight deadline gives a genuinely lossy (but nonzero) recovery.
    let part = Partitioning::rxc(3, 3, 4, 5, 4);
    let pair = default_pair_classes(3);
    let cm = ClassMap::from_levels(&part, vec![0, 1, 2], vec![0, 1, 2], &pair);
    let coding = CodingConfig {
        part: part.clone(),
        spec: CodeSpec::stacked(CodeKind::Uncoded),
        cm,
        workers: 9,
        latency: None,
    };
    let mut mats = Pcg64::seed_from(3);
    let a = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
    let b = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);

    // Same (A, B) served at growing deadlines. Received sets nest: the
    // fast worker's jobs (2 ms each) land in every window, the
    // straggler's (100 ms each, serialized) only in the generous one.
    //   T=1.0 (50 ms):  fast's 4–5 jobs, none of the straggler's
    //   T=30  (1.5 s):  everything
    let mut rng = Pcg64::seed_from(4);
    let deadlines = [1.0, 30.0];
    let mut outcomes = Vec::new();
    for &t_max in &deadlines {
        let req = MatmulRequest {
            a_id: 0,
            a: a.clone(),
            b: b.clone(),
            t_max,
            score: true,
        };
        outcomes.push(server.serve_request(&coding, &req, &mut rng).unwrap());
    }

    // tight deadline: the straggler's results are not in
    let tight = &outcomes[0];
    assert!(
        tight.late + tight.missing() > 0,
        "straggler should miss the tight deadline: {tight:?}"
    );
    assert!(tight.outcome.received < 9);
    // the fast worker's sub-products decode (uncoded ⇒ per-packet) …
    assert!(tight.outcome.recovered > 0, "{tight:?}");
    // … but the straggler's are missing, so the loss is real
    assert!(tight.outcome.normalized_loss > 0.0);

    // generous deadline: everything lands, exact product
    let generous = &outcomes[1];
    assert_eq!(generous.outcome.received, 9, "{generous:?}");
    assert_eq!(generous.outcome.recovered, 9);
    assert!(generous.outcome.normalized_loss < 1e-9);

    // paper-shaped behavior: loss monotone non-increasing in the deadline
    for w in outcomes.windows(2) {
        assert!(
            w[1].outcome.normalized_loss
                <= w[0].outcome.normalized_loss + 1e-9,
            "loss must not grow with the deadline: {} then {}",
            w[0].outcome.normalized_loss,
            w[1].outcome.normalized_loss
        );
    }

    // repeated-A stream: the second request hit the encoded-block cache
    assert_eq!(outcomes[0].cache_hit, Some(false));
    assert_eq!(outcomes[1].cache_hit, Some(true));
    let stats = server.cache_stats();
    assert!(stats.hits > 0);

    // clean shutdown: both workers exit via the protocol
    server.shutdown();
    let fast_stats = fast.join().unwrap();
    let slow_stats = slow.join().unwrap();
    assert!(fast_stats.clean_shutdown);
    assert!(slow_stats.clean_shutdown);
    assert!(fast_stats.jobs > 0);
    // the straggler computed every job too — its results were just late
    assert!(slow_stats.jobs > 0);
}

/// Losing a worker mid-stream must not take the service down: the
/// registry notices the dead connection and the survivors keep serving.
#[test]
fn tcp_cluster_survives_worker_death() {
    let mut transport = TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr = transport.local_addr();
    let stayer = spawn_tcp_worker(addr.clone(), "stayer", 0.04);
    // this worker dies after its first request batch: simulate by a
    // worker thread that drops the connection after a short wait
    let quitter_addr = addr.clone();
    let quitter = std::thread::spawn(move || {
        let mut conn = TcpConn::connect(&quitter_addr).expect("connect");
        use uepmm::cluster::{Connection, Msg};
        conn.send(&Msg::Hello { agent: "quitter".to_string() }).unwrap();
        match conn.recv().unwrap() {
            Msg::Welcome { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        // absorb whatever arrives for a moment, then vanish without
        // replying to anything
        std::thread::sleep(Duration::from_millis(80));
        drop(conn);
    });

    let mut server = ClusterServer::new(ClusterConfig {
        deadline: DeadlineMode::Wall,
        time_scale: TIME_SCALE,
        heartbeat_timeout: Duration::from_millis(200),
        late_drain: Duration::from_millis(20),
        ..ClusterConfig::default()
    });
    let joined = server
        .accept_workers(&mut transport, 2, Duration::from_secs(20))
        .unwrap();
    assert_eq!(joined, 2);

    let part = Partitioning::rxc(3, 3, 4, 5, 4);
    let pair = default_pair_classes(3);
    let cm = ClassMap::from_levels(&part, vec![0, 1, 2], vec![0, 1, 2], &pair);
    let coding = CodingConfig {
        part,
        spec: CodeSpec::stacked(CodeKind::Uncoded),
        cm,
        workers: 9,
        latency: None,
    };
    let mut mats = Pcg64::seed_from(9);
    let a = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
    let mut rng = Pcg64::seed_from(10);

    // let the quitter vanish, then stream; the first request may still
    // strand jobs on the not-yet-detected dead connection, but once the
    // registry has noticed, dispatch fails over and full recovery resumes
    quitter.join().unwrap();
    let mut served_after_death = 0;
    for req in 0..3 {
        let live_before = server.live_workers();
        let b = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);
        let out = server
            .serve_request(
                &coding,
                &MatmulRequest { a_id: 0, a: a.clone(), b, t_max: 30.0, score: true },
                &mut rng,
            )
            .unwrap();
        let _ = server.heartbeat();
        if live_before == 1 {
            served_after_death += 1;
            // every job went to the stayer, so a generous deadline fully
            // decodes despite the lost worker
            assert_eq!(out.outcome.recovered, 9, "req {req}: {out:?}");
        }
    }
    assert!(
        served_after_death > 0,
        "the quitter never died from the registry's point of view"
    );
    server.shutdown();
    assert!(stayer.join().unwrap().clean_shutdown);
}
