//! Multi-tenant serve-plane integration: three concurrent clients over
//! one loopback front door, asserting the PR's three contracts:
//!
//! * **bit-identity** — each client's outcomes (recovered set, `Ĉ`
//!   bits, loss bits, late count) are identical whether the three
//!   sessions run concurrently interleaved or strictly one at a time,
//!   because the engine settles every request with collect-all
//!   virtual-time semantics;
//! * **fairness** — deficit round robin bounds any session's
//!   consecutive-dispatch burst by the quantum and keeps dispatch
//!   counts of always-ready sessions within one quantum of each other;
//! * **admission** — the `max_sessions + 1`-th concurrent open is
//!   rejected with a positive backoff hint, and the seat frees on
//!   close.

use std::thread;

use uepmm::api::{Backend, ClusterBackend, Request, RunReport, Session, UepmmError};
use uepmm::cluster::{
    spawn_loopback_workers, Connection, DrrScheduler, LoopbackDialer,
    LoopbackTransport, ServePlane, ServiceConfig, ServiceReport, WorkerConfig,
};
use uepmm::coding::{CodeKind, CodeSpec, WindowPolynomial};
use uepmm::linalg::Matrix;
use uepmm::partition::{ClassMap, Partitioning};
use uepmm::rng::Pcg64;

const WORKERS: usize = 14;
const REQUESTS: usize = 2;

fn part() -> Partitioning {
    Partitioning::rxc(3, 3, 4, 5, 4)
}

fn code() -> CodeSpec {
    CodeSpec::stacked(CodeKind::EwUep(WindowPolynomial::paper_table3()))
}

/// Pinned classes, so the stream's cache key does not depend on each
/// request's fresh `B` (same rationale as `tests/api_backends.rs`).
fn pinned_cm() -> ClassMap {
    let pair = uepmm::partition::default_pair_classes(3);
    ClassMap::from_levels(&part(), vec![0, 1, 2], vec![0, 1, 2], &pair)
}

fn remote_session(dialer: &LoopbackDialer, name: &str, seed: u64) -> Session {
    let conn: Box<dyn Connection> = Box::new(dialer.dial(name).unwrap());
    let backend = ClusterBackend::connect_over(conn, name).unwrap();
    Session::builder()
        .partitioning(part())
        .code(code())
        .classes(pinned_cm())
        .workers(WORKERS)
        .latency(uepmm::latency::LatencyModel::exp(1.0))
        .deadline(1.1)
        .score(true)
        .seed(seed)
        .backend(backend)
        .build()
        .unwrap()
}

/// One tenant's workload: a repeated-`A` stream of `REQUESTS` requests,
/// fully deterministic in `seed`.
fn run_tenant(dialer: &LoopbackDialer, name: &str, seed: u64) -> Vec<RunReport> {
    let mut session = remote_session(dialer, name, seed);
    let mut mats = Pcg64::with_stream(seed, 1);
    let a = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
    let mut reports = Vec::new();
    for _ in 0..REQUESTS {
        let b = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);
        reports.push(session.run(Request::new(0, a.clone(), b)).unwrap());
    }
    session.shutdown().unwrap();
    reports
}

fn start_plane(
    cfg: ServiceConfig,
    expected_sessions: usize,
) -> (LoopbackDialer, thread::JoinHandle<ServiceReport>) {
    let (mut transport, dialer) = LoopbackTransport::new();
    let handle =
        thread::spawn(move || ServePlane::new(cfg).run(&mut transport, expected_sessions));
    (dialer, handle)
}

/// The outcome bits that must not depend on client interleaving.
fn fingerprint(reports: &[RunReport]) -> Vec<(usize, usize, Vec<usize>, Vec<u64>, u64, usize)> {
    reports
        .iter()
        .map(|r| {
            (
                r.outcome.received,
                r.outcome.recovered,
                r.outcome.per_class_recovered.clone(),
                r.outcome.c_hat.data().iter().map(|v| v.to_bits()).collect(),
                r.outcome.normalized_loss.to_bits(),
                r.late,
            )
        })
        .collect()
}

/// Three tenants served concurrently decode bit-identically to the same
/// three tenants served one at a time: the serve plane's multiplexing
/// is invisible in the results.
#[test]
fn concurrent_tenants_decode_bit_identically_to_sequential() {
    let seeds: [(&str, u64); 3] = [("t-a", 101), ("t-b", 202), ("t-c", 303)];

    // concurrent: three client threads share one plane and fleet
    let (dialer, plane) = start_plane(ServiceConfig::default(), 3);
    let workers = spawn_loopback_workers(&dialer, 3, &WorkerConfig::default());
    let concurrent: Vec<Vec<RunReport>> = {
        let handles: Vec<_> = seeds
            .iter()
            .map(|&(name, seed)| {
                let dialer = dialer.clone();
                thread::spawn(move || run_tenant(&dialer, name, seed))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    };
    let report = plane.join().unwrap();
    for h in workers {
        assert!(h.join().unwrap().unwrap().clean_shutdown);
    }
    assert_eq!(report.sessions, 3);
    assert_eq!(report.served, (3 * REQUESTS) as u64);
    assert_eq!(report.rejected, 0);

    // sequential: a fresh plane and fleet, one tenant at a time
    let (dialer, plane) = start_plane(ServiceConfig::default(), 3);
    let workers = spawn_loopback_workers(&dialer, 3, &WorkerConfig::default());
    let sequential: Vec<Vec<RunReport>> = seeds
        .iter()
        .map(|&(name, seed)| run_tenant(&dialer, name, seed))
        .collect();
    plane.join().unwrap();
    for h in workers {
        assert!(h.join().unwrap().unwrap().clean_shutdown);
    }

    for (conc, seq) in concurrent.iter().zip(&sequential) {
        assert!(conc.iter().all(|r| r.outcome.recovered > 0));
        assert_eq!(fingerprint(conc), fingerprint(seq));
    }
    // the decode really was remote: reports carry the remote backend tag
    assert!(concurrent
        .iter()
        .flatten()
        .all(|r| r.backend == "cluster-remote"));
}

/// DRR bounds, asserted on the shared scheduler type the engine embeds:
/// with every session always ready, (a) no session bursts more than
/// `quantum` consecutive dispatches, (b) total dispatch counts stay
/// within one quantum of each other at every prefix.
#[test]
fn drr_dispatch_counts_stay_within_one_quantum() {
    let quantum = 3u32;
    let mut sched = DrrScheduler::new(quantum);
    for s in [1u64, 2, 3] {
        sched.add_session(s, u32::MAX);
    }
    let order: Vec<u64> =
        (0..90).map(|_| sched.next(|_| true).unwrap()).collect();
    let mut counts = std::collections::HashMap::new();
    let mut burst = 0u32;
    let mut prev = 0u64;
    for &s in &order {
        burst = if s == prev { burst + 1 } else { 1 };
        prev = s;
        assert!(burst <= quantum, "burst of {burst} for session {s}");
        *counts.entry(s).or_insert(0u32) += 1;
        let max = counts.values().max().unwrap();
        let min = [1u64, 2, 3]
            .iter()
            .map(|k| counts.get(k).copied().unwrap_or(0))
            .min()
            .unwrap();
        assert!(
            max - min <= quantum,
            "unfair prefix: counts {counts:?}"
        );
    }
    assert!(counts.values().all(|&c| c == 30));
}

/// The session table admits exactly `max_sessions` concurrent tenants;
/// the next open is rejected with a positive backoff, and the seat
/// frees the moment a tenant closes.
#[test]
fn session_table_rejects_then_readmits() {
    let cfg = ServiceConfig { max_sessions: 2, ..ServiceConfig::default() };
    let (dialer, plane) = start_plane(cfg, 3);
    let workers = spawn_loopback_workers(&dialer, 2, &WorkerConfig::default());

    let connect = |name: &str| -> Result<ClusterBackend, UepmmError> {
        let conn: Box<dyn Connection> = Box::new(dialer.dial(name).unwrap());
        ClusterBackend::connect_over(conn, name)
    };
    let mut a = connect("t-a").unwrap();
    let mut b = connect("t-b").unwrap();
    match connect("t-c") {
        Err(UepmmError::Rejected { retry_after_ms, reason }) => {
            assert!(retry_after_ms > 0);
            assert!(reason.contains("session table"), "{reason}");
        }
        other => panic!("expected a reject, got {:?}", other.map(|_| "backend")),
    }
    // close one seat, and the rejected tenant gets in and is served
    b.shutdown().unwrap();
    let reports = run_tenant(&dialer, "t-c", 404);
    assert_eq!(reports.len(), REQUESTS);
    assert!(reports.iter().all(|r| r.outcome.recovered > 0));
    a.shutdown().unwrap();
    let report = plane.join().unwrap();
    for h in workers {
        assert!(h.join().unwrap().unwrap().clean_shutdown);
    }
    assert_eq!(report.sessions, 3);
    assert_eq!(report.rejected, 1);
    assert_eq!(report.served, REQUESTS as u64);
}
