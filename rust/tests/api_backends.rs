//! The unified client API's core guarantees, asserted end to end:
//!
//! * **backend equivalence** — the same seed and session configuration
//!   produce a *bit-identical* `Outcome` (recovered set, per-block
//!   values, loss bits) across `InProcessBackend`, `PooledBackend`,
//!   and a loopback `ClusterBackend`;
//! * **anytime progress** — the `Progress` stream is per-arrival,
//!   monotone in recovered count, non-increasing in loss (r×c), and
//!   consistent with the final outcome;
//! * **batched ≡ sequential** — pipelined `submit_batch` + out-of-order
//!   `wait` reproduces one-at-a-time `run` exactly;
//! * **selective ≡ honest** — the coefficient-only training fast path
//!   recovers the same set and assembles the same blocks (to fp
//!   tolerance) as honest job compute.

use uepmm::api::{
    Backend, ClusterBackend, Compute, InProcessBackend, PollState, PooledBackend,
    Request, RunReport, Session, UepmmError,
};
use uepmm::cluster::{ClusterConfig, DeadlineMode, WorkerConfig};
use uepmm::coding::{CodeKind, CodeSpec, WindowPolynomial};
use uepmm::linalg::Matrix;
use uepmm::partition::{ClassMap, Partitioning};
use uepmm::rng::Pcg64;

const WORKERS: usize = 14;

fn part() -> Partitioning {
    Partitioning::rxc(3, 3, 4, 5, 4)
}

fn code() -> CodeSpec {
    CodeSpec::stacked(CodeKind::EwUep(WindowPolynomial::paper_table3()))
}

/// Pinned classes: with auto-classification the class map would depend
/// on each request's fresh `B`, which would split the cache key across
/// a repeated-`A` stream and make hit/miss assertions seed-dependent.
fn pinned_cm() -> ClassMap {
    let pair = uepmm::partition::default_pair_classes(3);
    ClassMap::from_levels(&part(), vec![0, 1, 2], vec![0, 1, 2], &pair)
}

fn session_with(backend: impl Backend + 'static, seed: u64) -> Session {
    Session::builder()
        .partitioning(part())
        .code(code())
        .classes(pinned_cm())
        .workers(WORKERS)
        .latency(uepmm::latency::LatencyModel::exp(1.0))
        .deadline(1.1)
        .score(true)
        .seed(seed)
        .backend(backend)
        .build()
        .unwrap()
}

/// The repeated-`A` stream every equivalence check runs: two weight
/// matrices, fresh activations per request, one guaranteed cache hit.
fn run_stream(mut session: Session) -> Vec<RunReport> {
    let mut mats = Pcg64::with_stream(99, 0);
    let a0 = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
    let a1 = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
    let stream = [(0u64, &a0), (0, &a0), (1, &a1), (0, &a0)];
    let mut reports = Vec::new();
    for &(a_id, a) in &stream {
        let b = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);
        reports.push(session.run(Request::new(a_id, a.clone(), b)).unwrap());
    }
    session.shutdown().unwrap();
    reports
}

fn assert_outcomes_bit_identical(x: &RunReport, y: &RunReport, ctx: &str) {
    assert_eq!(x.outcome.received, y.outcome.received, "{ctx}: received");
    assert_eq!(x.outcome.recovered, y.outcome.recovered, "{ctx}: recovered");
    assert_eq!(
        x.outcome.per_class_recovered, y.outcome.per_class_recovered,
        "{ctx}: per-class"
    );
    assert_eq!(x.outcome.c_hat.data(), y.outcome.c_hat.data(), "{ctx}: c_hat");
    assert_eq!(
        x.outcome.loss.to_bits(),
        y.outcome.loss.to_bits(),
        "{ctx}: loss bits"
    );
    assert_eq!(
        x.outcome.normalized_loss.to_bits(),
        y.outcome.normalized_loss.to_bits(),
        "{ctx}: normalized loss bits"
    );
}

#[test]
fn backends_produce_bit_identical_outcomes() {
    let seed = 21;
    let inproc = run_stream(session_with(InProcessBackend::serial(), seed));
    let pooled = run_stream(session_with(PooledBackend::spawn(2).unwrap(), seed));
    let cluster = run_stream(session_with(
        ClusterBackend::loopback(
            3,
            ClusterConfig {
                deadline: DeadlineMode::Virtual,
                time_scale: 0.0,
                cache_capacity: 0,
                ..ClusterConfig::default()
            },
            WorkerConfig::default(),
            std::time::Duration::from_secs(30),
        )
        .unwrap(),
        seed,
    ));
    assert_eq!(inproc.len(), 4);
    for i in 0..inproc.len() {
        assert_outcomes_bit_identical(&inproc[i], &pooled[i], &format!("req {i} pooled"));
        assert_outcomes_bit_identical(
            &inproc[i],
            &cluster[i],
            &format!("req {i} cluster"),
        );
        // the repeated-A stream must hit the session cache identically
        let want_hit = i != 0 && i != 2;
        for r in [&inproc[i], &pooled[i], &cluster[i]] {
            assert_eq!(r.cache_hit, Some(want_hit), "req {i} cache on {}", r.backend);
        }
    }
    // sanity: a partial deadline actually cut something off somewhere,
    // otherwise the equivalence above is vacuous
    assert!(
        inproc.iter().any(|r| r.outcome.received < WORKERS),
        "deadline never binding: raise workers or lower t_max"
    );
    assert!(inproc.iter().any(|r| r.outcome.recovered > 0));
}

#[test]
fn progress_stream_is_monotone_and_matches_the_outcome() {
    for seed in 1..=8u64 {
        let mut session = session_with(InProcessBackend::serial(), seed);
        let mut mats = Pcg64::with_stream(7 + seed, 0);
        let a = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
        let b = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);
        let report = session.run(Request::new(0, a, b)).unwrap();
        let p = &report.progress;
        assert_eq!(p.len(), report.outcome.received, "seed {seed}: one event per arrival");
        assert!(p.loss_non_increasing(), "seed {seed}");
        let mut prev_recovered = 0;
        let mut prev_t = 0.0;
        for (i, e) in p.events().iter().enumerate() {
            assert_eq!(e.received, i + 1, "seed {seed}");
            assert!(e.recovered >= prev_recovered, "seed {seed}");
            assert!(e.elapsed >= prev_t, "seed {seed}: absorb order is by arrival");
            assert!(e.elapsed <= 1.1 + 1e-12, "seed {seed}: event past deadline");
            assert!(
                e.normalized_loss <= 1.0 + 1e-9,
                "seed {seed}: running loss above energy"
            );
            prev_recovered = e.recovered;
            prev_t = e.elapsed;
        }
        if let Some(last) = p.last() {
            assert_eq!(last.recovered, report.outcome.recovered, "seed {seed}");
            // Gram-based running loss vs honest ‖C − Ĉ‖²: same quantity,
            // different accumulation — equal to fp tolerance
            assert!(
                (last.loss - report.outcome.loss).abs()
                    <= 1e-6 * (1.0 + report.outcome.loss),
                "seed {seed}: progress loss {} vs outcome loss {}",
                last.loss,
                report.outcome.loss
            );
        }
    }
}

#[test]
fn in_process_polling_streams_one_arrival_at_a_time_and_cancel_is_anytime() {
    let mut session = session_with(InProcessBackend::serial(), 5);
    let mut mats = Pcg64::with_stream(55, 0);
    let a = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
    let b = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);

    // a generous per-request deadline guarantees every arrival is
    // in-deadline, so two polls absorb exactly two arrivals
    let h = session.submit(Request::new(0, a, b).deadline(50.0)).unwrap();
    let mut events = 0;
    for _ in 0..2 {
        match session.poll(h).unwrap() {
            PollState::Pending(new) => events += new.len(),
            PollState::Ready(_) => panic!("finished after two polls?"),
        }
    }
    assert_eq!(events, 2, "one event per poll step");
    let partial = session.cancel(h).unwrap().expect("work had started");
    assert_eq!(partial.outcome.received, 2);
    assert_eq!(partial.progress.len(), 2);
    assert!(partial.outcome.recovered <= 2, "two equations determine at most two");
    assert_eq!(
        partial.outcome.per_class_recovered.iter().sum::<usize>(),
        partial.outcome.recovered
    );
    // the canceled handle is consumed
    assert!(matches!(session.poll(h), Err(UepmmError::Config(_))));
}

#[test]
fn batched_submission_is_equivalent_to_sequential_runs() {
    let sequential = run_stream(session_with(PooledBackend::spawn(2).unwrap(), 31));

    let mut session = session_with(PooledBackend::spawn(2).unwrap(), 31);
    let mut mats = Pcg64::with_stream(99, 0);
    let a0 = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
    let a1 = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
    let stream = [(0u64, &a0), (0, &a0), (1, &a1), (0, &a0)];
    let reqs: Vec<Request> = stream
        .iter()
        .map(|&(a_id, a)| {
            let b = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);
            Request::new(a_id, a.clone(), b)
        })
        .collect();
    let handles = session.submit_batch(reqs).unwrap();
    // wait out of order: the FIFO pipeline must still serve in
    // submission order, keeping the RNG-replay deterministic
    let mut batched: Vec<Option<RunReport>> = (0..handles.len()).map(|_| None).collect();
    for &i in &[2usize, 0, 3, 1] {
        batched[i] = Some(session.wait(handles[i]).unwrap());
    }
    session.shutdown().unwrap();
    for (i, (seq, bat)) in sequential.iter().zip(batched.iter()).enumerate() {
        assert_outcomes_bit_identical(
            seq,
            bat.as_ref().unwrap(),
            &format!("batched req {i}"),
        );
    }
}

#[test]
fn selective_compute_matches_honest_jobs() {
    let build = |compute| {
        Session::builder()
            .partitioning(part())
            .code(code())
            .auto_classes(3)
            .workers(WORKERS)
            .latency(uepmm::latency::LatencyModel::exp(1.0))
            .deadline(0.9)
            .score(true)
            .compute(compute)
            .cache_capacity(0)
            .seed(13)
            .backend(InProcessBackend::serial())
            .build()
            .unwrap()
    };
    let mut mats = Pcg64::with_stream(42, 0);
    let a = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
    let b = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);
    // same seed ⇒ same packet draw and same delays in both modes (the
    // encode path consumes no extra RNG beyond the packet draw)
    let honest = build(Compute::Honest)
        .run(Request::new(0, a.clone(), b.clone()))
        .unwrap();
    let selective = build(Compute::Selective).run(Request::new(0, a, b)).unwrap();
    assert_eq!(honest.outcome.received, selective.outcome.received);
    assert_eq!(honest.outcome.recovered, selective.outcome.recovered);
    assert_eq!(
        honest.outcome.per_class_recovered,
        selective.outcome.per_class_recovered
    );
    // honest values go through the decoder's elimination; selective ones
    // are computed directly — identical up to fp tolerance
    assert!(
        honest.outcome.c_hat.allclose(&selective.outcome.c_hat, 1e-9),
        "selective assembly diverged from honest decode"
    );
    assert!((honest.outcome.loss - selective.outcome.loss).abs() <= 1e-6 * (1.0 + honest.outcome.loss));
    assert_eq!(selective.cache_hit, None, "selective mode bypasses the cache");
}

#[test]
fn unscored_requests_have_nan_loss_but_full_progress_counts() {
    let mut session = session_with(PooledBackend::spawn(2).unwrap(), 77);
    let mut mats = Pcg64::with_stream(11, 0);
    let a = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
    let b = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);
    let report = session
        .run(Request::new(0, a, b).scored(false).deadline(50.0))
        .unwrap();
    session.shutdown().unwrap();
    assert!(report.outcome.loss.is_nan());
    assert!(report.outcome.normalized_loss.is_nan());
    assert_eq!(report.progress.len(), report.outcome.received);
    assert!(report.progress.loss_non_increasing(), "vacuous on NaN losses");
    assert!(report.progress.refinements() > 0);
    assert!(report.outcome.recovered > 0);
}
