//! Offline stand-in for the `anyhow` crate (the real one is not in the
//! hermetic vendor set). Implements exactly the API subset this
//! workspace uses — [`Error`], [`Result`], the [`Context`] extension on
//! `Result`/`Option`, and the `anyhow!`/`bail!`/`ensure!` macros — with
//! upstream-compatible formatting: `{}` prints the outermost message,
//! `{:#}` the full colon-joined cause chain, and `{:?}` the message plus
//! a "Caused by:" list.

use std::error::Error as StdError;
use std::fmt;

/// A dynamic error carrying a chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn from_std<E: StdError>(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement std::error::Error, so this
// blanket impl cannot overlap the reflexive `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::from_std(err)
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from_std(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from_std(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_outermost_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing thing");
    }

    #[test]
    fn debug_prints_cause_list() {
        let e: Error = Error::from(io_err()).context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("0: missing thing"));
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        let e = none.context("was none").unwrap_err();
        assert_eq!(format!("{e}"), "was none");
        let r: std::result::Result<u32, std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("op {}", 7)).unwrap_err();
        assert_eq!(format!("{e:#}"), "op 7: missing thing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        let e = anyhow!("n = {}", 4);
        assert_eq!(format!("{e}"), "n = 4");
    }
}
