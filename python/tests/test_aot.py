"""AOT path smoke: lowering produces parseable HLO text and a manifest
consistent with the contract the Rust runtime parses (runtime/manifest.rs)."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def artifact_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.build(str(out), quick=True)
    return str(out)


def test_manifest_structure(artifact_dir):
    with open(os.path.join(artifact_dir, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    arts = manifest["artifacts"]
    assert len(arts) >= 5  # 3 matmuls + encode + worker_product (quick set)
    names = {a["name"] for a in arts}
    assert "block_matmul_64x32x64" in names
    assert "block_matmul_64x96x64" in names
    assert f"uep_encode_3x{aot.QS_U}x{aot.QS_H}" in names
    for a in arts:
        assert os.path.exists(os.path.join(artifact_dir, a["path"]))
        for t in a["inputs"] + a["outputs"]:
            assert t["dtype"] == "f32"
            assert all(isinstance(d, int) and d > 0 for d in t["shape"])


def test_hlo_text_is_hlo(artifact_dir):
    path = os.path.join(artifact_dir, "block_matmul_64x32x64.hlo.txt")
    text = open(path).read()
    # HLO text starts with the module header and declares an ENTRY
    assert text.lstrip().startswith("HloModule")
    assert "ENTRY" in text
    # lowered with return_tuple=True: the root is a tuple
    assert "tuple" in text


def test_matmul_artifact_shapes_recorded(artifact_dir):
    with open(os.path.join(artifact_dir, "manifest.json")) as f:
        manifest = json.load(f)
    e = next(a for a in manifest["artifacts"] if a["name"] == "block_matmul_64x64x64")
    assert e["kind"] == "matmul"
    assert e["inputs"][0]["shape"] == [64, 64]
    assert e["inputs"][1]["shape"] == [64, 64]
    assert e["outputs"][0]["shape"] == [64, 64]
