"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, dtypes and tile sizes; the kernels must match
`ref.py` to float tolerance on every draw. This is the core correctness
signal for the compute layer (DESIGN.md section 7).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.block_matmul import block_matmul
from compile.kernels.uep_encode import uep_encode
from compile.kernels.block_matmul import pick_tile, vmem_bytes

jax.config.update("jax_platform_name", "cpu")

DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# block_matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    tile=st.sampled_from([8, 16, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_random_shapes(m, k, n, tile, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = _rand(k1, (m, k), jnp.float32)
    y = _rand(k2, (k, n), jnp.float32)
    got = block_matmul(x, y, tile_m=tile, tile_n=tile, tile_k=tile)
    want = ref.block_matmul_ref(x, y)
    np.testing.assert_allclose(np.array(got), np.array(want), **_tol(jnp.float32))


@pytest.mark.parametrize("dtype", DTYPES)
def test_matmul_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    x = _rand(k1, (64, 96), dtype)
    y = _rand(k2, (96, 64), dtype)
    got = block_matmul(x, y, tile_m=32, tile_n=32, tile_k=32)
    assert got.dtype == dtype
    want = ref.block_matmul_ref(x, y)
    np.testing.assert_allclose(
        np.array(got, np.float32), np.array(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize(
    "shape",
    [
        (1, 1, 1),  # degenerate
        (64, 288, 64),  # quickstart stacked k=9 (9 * 32)
        (64, 100, 784),  # MNIST G_1 (Table VI)
        (784, 64, 100),  # MNIST V_1^* (Table VI)
        (17, 13, 29),  # primes: tiles clip to 1 on some axes
    ],
)
def test_matmul_paper_and_edge_shapes(shape):
    m, k, n = shape
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    x = _rand(k1, (m, k), jnp.float32)
    y = _rand(k2, (k, n), jnp.float32)
    got = block_matmul(x, y)
    np.testing.assert_allclose(
        np.array(got), np.array(ref.block_matmul_ref(x, y)), **_tol(jnp.float32)
    )


def test_matmul_rejects_mismatched_inner_dims():
    x = jnp.zeros((4, 5))
    y = jnp.zeros((6, 4))
    with pytest.raises(AssertionError):
        block_matmul(x, y)


def test_pick_tile_divides():
    for dim in [1, 7, 64, 96, 100, 288, 784]:
        for target in [8, 32, 128]:
            t = pick_tile(dim, target)
            assert dim % t == 0 and t <= max(dim, target)


def test_vmem_budget_of_default_schedule():
    # default 128^3 tiles: 3 * 128*128 * 4 bytes = 192 KiB << 16 MiB VMEM
    assert vmem_bytes(128, 128, 128) == 3 * 128 * 128 * 4
    assert vmem_bytes(128, 128, 128) < 16 * 2**20


# ---------------------------------------------------------------------------
# uep_encode
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(1, 6),
    u=st.integers(1, 64),
    h=st.integers(1, 64),
    tile=st.sampled_from([8, 32, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_encode_matches_ref_random(k, u, h, tile, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    coeffs = _rand(k1, (k,), jnp.float32)
    blocks = _rand(k2, (k, u, h), jnp.float32)
    got = uep_encode(coeffs, blocks, tile_u=tile, tile_h=tile)
    want = ref.uep_encode_ref(coeffs, blocks)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-4, atol=2e-4)


def test_encode_linearity():
    # encode(c1 + c2) == encode(c1) + encode(c2) — the property RLC
    # decoding relies on.
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    blocks = _rand(k1, (4, 32, 16), jnp.float32)
    c1 = _rand(k2, (4,), jnp.float32)
    c2 = _rand(k3, (4,), jnp.float32)
    lhs = uep_encode(c1 + c2, blocks)
    rhs = uep_encode(c1, blocks) + uep_encode(c2, blocks)
    np.testing.assert_allclose(np.array(lhs), np.array(rhs), rtol=1e-4, atol=1e-4)


def test_encode_unit_coefficient_selects_block():
    key = jax.random.PRNGKey(4)
    blocks = _rand(key, (3, 8, 8), jnp.float32)
    c = jnp.array([0.0, 1.0, 0.0], jnp.float32)
    got = uep_encode(c, blocks)
    np.testing.assert_allclose(np.array(got), np.array(blocks[1]), rtol=1e-6)
