"""L2 correctness: the manual-backprop MLP graph vs jax autodiff, and the
fused worker job vs its oracle composition."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _init_params(key, dims):
    params = []
    flat = []
    for i in range(len(dims) - 1):
        key, k1 = jax.random.split(key)
        v = jax.random.normal(k1, (dims[i], dims[i + 1]), jnp.float32) * 0.1
        b = jnp.zeros((dims[i + 1],), jnp.float32)
        params.append((v, b))
        flat += [v, b]
    return params, flat


def _batch(key, dims, batch=8):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (batch, dims[0]), jnp.float32)
    labels = jax.random.randint(k2, (batch,), 0, dims[-1])
    y = jax.nn.one_hot(labels, dims[-1], dtype=jnp.float32)
    return x, y


def test_manual_backprop_matches_autodiff_small():
    dims = (12, 8, 6, 4)
    params, flat = _init_params(jax.random.PRNGKey(0), dims)
    x, y = _batch(jax.random.PRNGKey(1), dims)
    loss, dv1, db1, dv2, db2, dv3, db3 = model.mlp_step(*flat, x, y)
    # autodiff oracle on the plain-jnp loss
    loss_ref = model.mlp_loss_for_grad(*flat, x, y)
    np.testing.assert_allclose(float(loss), float(loss_ref), rtol=1e-5)
    grads = jax.grad(model.mlp_loss_for_grad, argnums=(0, 1, 2, 3, 4, 5))(*flat, x, y)
    for got, want in zip([dv1, db1, dv2, db2, dv3, db3], grads):
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), batch=st.integers(1, 16))
def test_manual_backprop_matches_autodiff_random(seed, batch):
    dims = (10, 7, 5, 3)
    params, flat = _init_params(jax.random.PRNGKey(seed), dims)
    x, y = _batch(jax.random.PRNGKey(seed + 1), dims, batch)
    outs = model.mlp_step(*flat, x, y)
    grads = jax.grad(model.mlp_loss_for_grad, argnums=(0, 1, 2, 3, 4, 5))(*flat, x, y)
    for got, want in zip(outs[1:], grads):
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-3, atol=1e-4)


def test_mlp_paper_shapes():
    """Table VI ABI: the artifact signature the Rust runtime loads."""
    dims = model.MLP_DIMS
    params, flat = _init_params(jax.random.PRNGKey(2), dims)
    x, y = _batch(jax.random.PRNGKey(3), dims, model.BATCH)
    outs = model.mlp_step(*flat, x, y)
    assert outs[0].shape == ()
    assert outs[1].shape == (784, 100) and outs[2].shape == (100,)
    assert outs[3].shape == (100, 200) and outs[4].shape == (200,)
    assert outs[5].shape == (200, 10) and outs[6].shape == (10,)
    (logits,) = model.mlp_logits(*flat, x)
    assert logits.shape == (model.BATCH, 10)


def test_worker_product_matches_oracle():
    key = jax.random.PRNGKey(5)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ca = jax.random.normal(k1, (3,), jnp.float32)
    ab = jax.random.normal(k2, (3, 16, 8), jnp.float32)
    cb = jax.random.normal(k3, (3,), jnp.float32)
    bb = jax.random.normal(k4, (3, 8, 16), jnp.float32)
    got = model.worker_product(ca, ab, cb, bb)
    want = ref.worker_product_ref(ca, ab, cb, bb)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-4)


def test_worker_product_linearity_in_payload():
    """The identity the Stacked decoder relies on: the fused job equals
    the Khatri-Rao combination of individual sub-products."""
    key = jax.random.PRNGKey(6)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ca = jax.random.normal(k1, (2,), jnp.float32)
    ab = jax.random.normal(k2, (2, 8, 4), jnp.float32)
    cb = jax.random.normal(k3, (2,), jnp.float32)
    bb = jax.random.normal(k4, (2, 4, 8), jnp.float32)
    got = model.worker_product(ca, ab, cb, bb)
    want = sum(
        float(ca[i]) * float(cb[j]) * (ab[i] @ bb[j])
        for i in range(2)
        for j in range(2)
    )
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-4)
