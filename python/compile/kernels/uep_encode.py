"""UEP encoding Pallas kernel: coefficient-weighted block reduction.

The PS-side encode step of paper eq. (17): given `k` stacked sub-blocks
`A_1..A_k` (shape `(k, U, H)`) and RLC coefficients `c (k,)`, produce
`W = sum_i c_i A_i`.

This is memory-bound (one multiply-add per element), so the schedule
streams one `(TU, TH)` tile of every block per grid step and reduces over
the leading axis in-register; only the running output tile lives in VMEM.
On TPU the coefficient vector would sit in SMEM — here it rides along as
a tiny VMEM block (interpret mode has no SMEM distinction).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .block_matmul import pick_tile


def _encode_kernel(coeff_ref, blocks_ref, o_ref):
    # blocks_ref: (k, TU, TH); coeff_ref: (k,)
    c = coeff_ref[...]
    o_ref[...] = jnp.einsum(
        "k,kuh->uh", c.astype(jnp.float32), blocks_ref[...].astype(jnp.float32)
    ).astype(o_ref.dtype)


def uep_encode(coeffs, blocks, *, tile_u: int = 256, tile_h: int = 256):
    """`sum_i coeffs[i] * blocks[i]` via a Pallas kernel.

    Args:
        coeffs: `(k,)` RLC coefficients.
        blocks: `(k, U, H)` stacked sub-blocks.
    Returns:
        `(U, H)` encoded matrix.
    """
    k, u, h = blocks.shape
    assert coeffs.shape == (k,), f"coeffs {coeffs.shape} vs blocks {blocks.shape}"
    tu = pick_tile(u, tile_u)
    th = pick_tile(h, tile_h)
    grid = (u // tu, h // th)
    return pl.pallas_call(
        _encode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k,), lambda i, j: (0,)),
            pl.BlockSpec((k, tu, th), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((tu, th), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((u, h), blocks.dtype),
        interpret=True,
    )(coeffs, blocks)
