"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with nothing but `jax.numpy`; pytest (python/tests/) asserts allclose
between kernel and oracle across shape/dtype sweeps. These oracles are
also what the L2 model would compute without the kernels, so they double
as the roofline baseline for the perf comparison.
"""

import jax.numpy as jnp


def block_matmul_ref(x, y):
    """Oracle for `kernels.block_matmul`."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def uep_encode_ref(coeffs, blocks):
    """Oracle for `kernels.uep_encode`: sum_i coeffs[i] * blocks[i]."""
    return jnp.einsum(
        "k,kuh->uh", coeffs.astype(jnp.float32), blocks.astype(jnp.float32)
    ).astype(blocks.dtype)


def worker_product_ref(a_coeffs, a_blocks, b_coeffs, b_blocks):
    """Oracle for the fused rank-one worker job (paper eq. 17):
    `(sum_i alpha_i A_i) @ (sum_j beta_j B_j)`."""
    wa = uep_encode_ref(a_coeffs, a_blocks)
    wb = uep_encode_ref(b_coeffs, b_blocks)
    return block_matmul_ref(wa, wb)
