"""Tiled block-matmul Pallas kernel — the worker's compute hot-spot.

A coded worker job is one dense product `W_A (U x kH) @ W_B (kH x Q)`
(paper eqs. 5-6; the `Stacked` encoding concatenates `k` sub-blocks along
the inner dimension). On TPU this kernel tiles the operands into
MXU-shaped VMEM blocks; `BlockSpec` below expresses exactly that
HBM->VMEM schedule. The contraction (K) axis is the innermost grid
dimension, so the output tile stays resident while partial products
accumulate into it — the standard Pallas matmul schedule.

VMEM budget per grid step (f32):
    tile_m*tile_k + tile_k*tile_n + tile_m*tile_n floats
= 192 KiB with the default 128x128x128 tiles, comfortably inside a
TPUv4 core's 16 MiB VMEM with room for double buffering. See
DESIGN.md section "Hardware adaptation" and EXPERIMENTS.md section Perf
for the tile sweep.

`interpret=True`: the CPU PJRT plugin cannot run Mosaic custom-calls;
interpret mode lowers the same schedule to plain HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_tile(dim: int, target: int) -> int:
    """Largest divisor of `dim` not exceeding `target`.

    Keeps every shape legal without padding; MXU-friendly shapes
    (multiples of 128) get full-size tiles.
    """
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ y[k,j].

    The output tile is f32 regardless of operand dtype, so partial sums
    accumulate at full precision across the K grid steps (the MXU's
    native behaviour for bf16 inputs).
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "tile_k"))
def block_matmul(x, y, *, tile_m: int = 128, tile_n: int = 128, tile_k: int = 128):
    """`x @ y` via a Pallas kernel tiled for VMEM/MXU.

    Tiles are clipped to the largest divisors of the operand dims not
    exceeding the requested sizes, so any shape works without padding.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {y.shape}"
    tm = pick_tile(m, tile_m)
    tn = pick_tile(n, tile_n)
    tk = pick_tile(k, tile_k)
    grid = (m // tm, n // tn, k // tk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)
    return out.astype(x.dtype)


def vmem_bytes(tile_m: int, tile_n: int, tile_k: int, dtype_bytes: int = 4) -> int:
    """Per-step VMEM footprint of the schedule (for the perf tables)."""
    return dtype_bytes * (tile_m * tile_k + tile_k * tile_n + tile_m * tile_n)
