"""AOT lowering: JAX/Pallas graphs -> HLO text artifacts + manifest.json.

Run once at build time (`make artifacts`); the Rust runtime loads the
HLO text through `HloModuleProto::from_text_file` and executes it on the
PJRT CPU client. Interchange is HLO *text*, NOT `.serialize()` — the
image's xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction
ids); the text parser reassigns ids (see /opt/xla-example/README.md).

Artifact inventory (DESIGN.md section 6):
  * block_matmul_MxKxN    — coded worker products (quickstart geometry:
    stacked windows k=1..9 over U=Q=64, H=32) + the six MNIST
    back-propagation shapes of Table VI;
  * uep_encode_KxUxH      — PS-side encode kernel;
  * worker_product_*      — fused rank-one job (eq. 17);
  * mlp_step / mlp_logits — the full MNIST training-step and inference
    graphs (centralized reference path).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.block_matmul import block_matmul
from .kernels.uep_encode import uep_encode

# Quickstart coded-matmul geometry: r x c with N=P=3, U=Q=64, H=32.
QS_U, QS_H, QS_Q = 64, 32, 64
QS_MAX_K = 9

# MNIST back-propagation matmul shapes (Table VI): (m, k, n).
MNIST_MM_SHAPES = [
    # G_i = G_{i+1} V_i^T
    (64, 10, 200),
    (64, 200, 100),
    (64, 100, 784),
    # V_i^* = X_i^T G_{i+1}
    (784, 64, 100),
    (100, 64, 200),
    (200, 64, 10),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def tensor_entry(s):
    return {"shape": list(s.shape), "dtype": "f32"}


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries = []
        os.makedirs(out_dir, exist_ok=True)

    def add(self, name: str, kind: str, fn, in_specs, n_outputs: int, out_specs=None):
        """Lower `fn` at `in_specs`, write HLO text, record manifest entry."""
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, path), "w") as f:
            f.write(text)
        if out_specs is None:
            out_shapes = jax.eval_shape(fn, *in_specs)
            if not isinstance(out_shapes, (tuple, list)):
                out_shapes = (out_shapes,)
            out_specs = list(out_shapes)
        assert len(out_specs) == n_outputs, f"{name}: output arity mismatch"
        self.entries.append(
            {
                "name": name,
                "path": path,
                "kind": kind,
                "inputs": [tensor_entry(s) for s in in_specs],
                "outputs": [tensor_entry(s) for s in out_specs],
            }
        )
        print(f"  wrote {name} ({len(text)} chars)")

    def finish(self):
        manifest = {"version": 1, "artifacts": self.entries}
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"manifest: {len(self.entries)} artifacts -> {self.out_dir}/manifest.json")


def matmul_fn(x, y):
    return (block_matmul(x, y),)


def encode_fn(c, blocks):
    return (uep_encode(c, blocks),)


def worker_product_fn(ca, ab, cb, bb):
    return (model.worker_product(ca, ab, cb, bb),)


def build(out_dir: str, quick: bool = False):
    b = Builder(out_dir)
    # --- coded worker products: quickstart geometry, stacked k = 1..9 ---
    max_k = 3 if quick else QS_MAX_K
    for k in range(1, max_k + 1):
        m, kk, n = QS_U, k * QS_H, QS_Q
        b.add(
            f"block_matmul_{m}x{kk}x{n}",
            "matmul",
            matmul_fn,
            [spec(m, kk), spec(kk, n)],
            1,
        )
    # --- MNIST back-propagation shapes (Table VI) ---
    if not quick:
        for m, kk, n in MNIST_MM_SHAPES:
            b.add(
                f"block_matmul_{m}x{kk}x{n}",
                "matmul",
                matmul_fn,
                [spec(m, kk), spec(kk, n)],
                1,
            )
    # --- PS-side encode kernel ---
    b.add(
        f"uep_encode_3x{QS_U}x{QS_H}",
        "uep_encode",
        encode_fn,
        [spec(3), spec(3, QS_U, QS_H)],
        1,
    )
    # --- fused rank-one worker job (eq. 17) ---
    b.add(
        f"worker_product_{QS_U}x{QS_H}x{QS_Q}_k3",
        "worker_product",
        worker_product_fn,
        [spec(3), spec(3, QS_U, QS_H), spec(3), spec(3, QS_H, QS_Q)],
        1,
    )
    # --- MNIST MLP training step + inference ---
    if not quick:
        d = model.MLP_DIMS
        bsz = model.BATCH
        param_specs = []
        for i in range(3):
            param_specs += [spec(d[i], d[i + 1]), spec(d[i + 1])]
        b.add(
            "mlp_step",
            "mlp_step",
            model.mlp_step,
            param_specs + [spec(bsz, d[0]), spec(bsz, d[3])],
            7,
            out_specs=[spec()]
            + [s for i in range(3) for s in (spec(d[i], d[i + 1]), spec(d[i + 1]))],
        )
        b.add(
            "mlp_logits",
            "mlp_logits",
            model.mlp_logits,
            param_specs + [spec(bsz, d[0])],
            1,
        )
    b.finish()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--quick", action="store_true", help="small artifact set (CI smoke)"
    )
    args = ap.parse_args()
    build(args.out, quick=args.quick)


if __name__ == "__main__":
    main()
