"""Layer-2 JAX model (build-time only): the paper's compute graphs.

Two families of graphs, all calling the L1 Pallas kernels:

* Worker jobs — the coded products the PS ships to workers:
  - `stacked_job`: one dense `W_A @ W_B` product (the Stacked encoding
    builds the factors on the Rust side, so the artifact is a plain
    block-matmul at the job's shape);
  - `worker_product`: the fused rank-one job of paper eq. (17):
    encode(A blocks) @ encode(B blocks).

* The MNIST-style MLP of paper section VII-A (784-100-200-10, Table VI)
  with *manual* back-propagation written exactly as the paper's eqs.
  (32)-(33) — `G_i = G_{i+1} V_i^T` and `V_i^* = X_i^T G_{i+1}` — so the
  distributed matmuls in the Rust training loop correspond one-to-one to
  matmuls in this graph. Verified against `jax.grad` in pytest.

Everything here is lowered once by `aot.py`; nothing imports this at
request time.
"""

import jax
import jax.numpy as jnp

from .kernels.block_matmul import block_matmul
from .kernels.uep_encode import uep_encode

# ---------------------------------------------------------------------------
# Worker jobs
# ---------------------------------------------------------------------------


def stacked_job(wa, wb):
    """A stacked coded product: one dense matmul at the job shape."""
    return block_matmul(wa, wb)


def worker_product(a_coeffs, a_blocks, b_coeffs, b_blocks):
    """The fused rank-one worker job of paper eq. (17)."""
    wa = uep_encode(a_coeffs, a_blocks)
    wb = uep_encode(b_coeffs, b_blocks)
    return block_matmul(wa, wb)


# ---------------------------------------------------------------------------
# MNIST MLP (paper section VII-A, Fig. 12, Table VI)
# ---------------------------------------------------------------------------

#: Layer widths of the MNIST model: 784 -> 100 -> 200 -> 10.
MLP_DIMS = (784, 100, 200, 10)
#: Mini-batch size (Table IV).
BATCH = 64


def mlp_param_shapes(dims=MLP_DIMS):
    """[(weight shape, bias shape)] per dense layer."""
    return [((dims[i], dims[i + 1]), (dims[i + 1],)) for i in range(len(dims) - 1)]


def mlp_forward(params, x):
    """Forward pass; returns (logits, activations per layer input).

    `activations[i]` is X_i, the input of dense layer i — the matrices
    the paper's eq. (33) multiplies.
    """
    activations = [x]
    h = x
    n_layers = len(params)
    for i, (v, b) in enumerate(params):
        h = block_matmul(h, v) + b
        if i + 1 < n_layers:
            h = jax.nn.relu(h)
        activations.append(h)
    return h, activations


def softmax_xent(logits, y_onehot):
    """Mean categorical cross-entropy."""
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def mlp_backward(params, activations, logits, y_onehot):
    """Manual back-propagation, paper eqs. (32)-(33).

    Returns (weight grads, bias grads), each a list per layer. Every
    matmul goes through the Pallas kernel — these are exactly the
    products the Rust coordinator distributes to coded workers.
    """
    batch = logits.shape[0]
    # dL/dlogits for mean softmax cross-entropy
    g = (jax.nn.softmax(logits) - y_onehot) / batch  # G_{I+1}
    weight_grads = []
    bias_grads = []
    for i in reversed(range(len(params))):
        v, _b = params[i]
        x_i = activations[i]
        # eq. (33): V_i^* = X_i^T G_{i+1}
        weight_grads.append(block_matmul(x_i.T, g))
        bias_grads.append(jnp.sum(g, axis=0))
        if i > 0:
            # eq. (32): G_i = G_{i+1} V_i^T ...
            g = block_matmul(g, v.T)
            # ... masked by the ReLU derivative of layer i's input
            g = g * (activations[i] > 0).astype(g.dtype)
    weight_grads.reverse()
    bias_grads.reverse()
    return weight_grads, bias_grads


def mlp_step(v1, b1, v2, b2, v3, b3, x, y_onehot):
    """One full training step's compute: loss + all gradients.

    Flat-argument signature so the AOT artifact has a stable ABI for the
    Rust runtime: inputs (V1,b1,V2,b2,V3,b3,X,Y), outputs
    (loss, dV1,db1,dV2,db2,dV3,db3).
    """
    params = [(v1, b1), (v2, b2), (v3, b3)]
    logits, acts = mlp_forward(params, x)
    loss = softmax_xent(logits, y_onehot)
    wg, bg = mlp_backward(params, acts, logits, y_onehot)
    return (loss, wg[0], bg[0], wg[1], bg[1], wg[2], bg[2])


def mlp_logits(v1, b1, v2, b2, v3, b3, x):
    """Inference-only graph (accuracy evaluation)."""
    logits, _ = mlp_forward([(v1, b1), (v2, b2), (v3, b3)], x)
    return (logits,)


def mlp_loss_for_grad(v1, b1, v2, b2, v3, b3, x, y_onehot):
    """Same loss built from plain jnp ops — the autodiff oracle used by
    pytest to validate the manual backward pass."""
    h = x
    params = [(v1, b1), (v2, b2), (v3, b3)]
    for i, (v, b) in enumerate(params):
        h = h @ v + b
        if i + 1 < len(params):
            h = jax.nn.relu(h)
    return softmax_xent(h, y_onehot)
