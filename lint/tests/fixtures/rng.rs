//! Lint fixture: ambient entropy fires on any path; the server-loop
//! and unordered-iteration rules stay quiet outside their scopes.
use std::collections::HashMap;

pub fn seed_badly() -> u64 {
    let _rng = StdRng::from_entropy();
    let _os = OsRng;
    let m: HashMap<u64, u64> = HashMap::new();
    m.get(&0).copied().unwrap_or(0)
}
