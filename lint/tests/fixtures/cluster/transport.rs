//! Lint fixture: the transport is allowlisted for wall-clock reads
//! (Wall-mode recv/accept deadlines are its job).
use std::time::Instant;

pub fn recv_deadline() -> Instant {
    Instant::now()
}
