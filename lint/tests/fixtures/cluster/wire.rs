//! Lint fixture: mini wire protocol with one frame variant that no
//! test ever constructs.
pub enum Msg {
    /// Worker → coordinator: register.
    Hello { agent: String },
    /// Coordinator → worker: one job.
    Job(u64),
    /// Coordinator → worker: drain and exit. (Uncovered on purpose.)
    Shutdown,
}

#[cfg(test)]
mod tests {
    use super::Msg;

    #[test]
    fn round_trips() {
        let _ = Msg::Hello { agent: String::new() };
        let _ = Msg::Job(7);
    }
}
