//! Lint fixture: a fake server-loop file. Not compiled by cargo —
//! only lexed by the lint's integration tests.
use std::collections::HashMap;
use std::time::Instant;

fn hot_loop(jobs: &HashMap<u64, f64>) {
    let t0 = Instant::now();
    let v = jobs.get(&1).unwrap();
    let w = jobs.get(&2).expect("present");
    if *v > *w {
        panic!("inverted");
    }
    let mut xs = vec![3.0_f64, f64::NAN, 1.0];
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let _ = (t0, xs);
}

fn negatives() {
    // .unwrap() and Instant::now() and partial_cmp in a comment: quiet
    /* block comment with panic! and HashMap stays quiet too */
    let s = "string with .unwrap() and partial_cmp and Instant::now()";
    let r = r#"raw string: HashMap::new().unwrap() SystemTime::now()"#;
    let lifetime: &'static str = "x";
    let fallback = Some(1_usize).unwrap_or(2);
    let _ = s.len() + r.len() + lifetime.len() + fallback;
}

fn justified() -> f64 {
    // lint:allow(no-wallclock-in-deterministic-paths) per-request wall telemetry; decode state never reads it
    let t = Instant::now();
    t.elapsed().as_secs_f64()
}

fn unjustified(m: &HashMap<u64, u64>) -> u64 {
    *m.get(&1).unwrap() // lint:allow(no-panic-in-server-loops)
}

#[cfg(test)]
mod tests {
    use std::time::Instant;

    #[test]
    fn test_code_is_exempt_from_server_rules() {
        let t = Instant::now();
        let v = vec![1_u64];
        assert_eq!(*v.first().unwrap(), 1);
        assert!(t.elapsed().as_secs_f64() >= 0.0);
    }
}
