//! Integration tests for the lint engine: known-violation fixtures,
//! literal/comment negatives, suppression behavior, stable ordering,
//! and the cross-file wire-coverage rule.

use std::path::Path;

use uepmm_lint::engine::{run, Finding, SourceFile};
use uepmm_lint::rules;

/// Load a fixture from disk under the path the rules will scope on
/// (`fixtures/cluster/...` keeps the `cluster/` scoping live).
fn fixture(rel: &str) -> SourceFile {
    let disk = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(rel);
    let src = std::fs::read_to_string(&disk)
        .unwrap_or_else(|e| panic!("fixture {rel}: {e}"));
    SourceFile::parse(&format!("fixtures/{rel}"), &src, false)
}

fn triples(findings: &[Finding]) -> Vec<(String, u32, String)> {
    findings
        .iter()
        .map(|f| (f.path.clone(), f.line, f.rule.clone()))
        .collect()
}

#[test]
fn fixture_findings_are_exactly_the_expected_set() {
    let files = vec![
        fixture("cluster/server.rs"),
        fixture("cluster/transport.rs"),
        fixture("cluster/wire.rs"),
        fixture("rng.rs"),
    ];
    let got = triples(&run(&files));
    let srv = "fixtures/cluster/server.rs".to_string();
    let want: Vec<(String, u32, String)> = vec![
        (srv.clone(), 3, rules::NO_UNORDERED.into()),
        (srv.clone(), 6, rules::NO_UNORDERED.into()),
        (srv.clone(), 7, rules::NO_WALLCLOCK.into()),
        (srv.clone(), 8, rules::NO_PANIC.into()),
        (srv.clone(), 9, rules::NO_PANIC.into()),
        (srv.clone(), 11, rules::NO_PANIC.into()),
        (srv.clone(), 14, rules::NO_PANIC.into()),
        (srv.clone(), 14, rules::NO_PARTIAL_CMP.into()),
        (srv.clone(), 34, rules::NO_UNORDERED.into()),
        // the trailing lint:allow on line 35 suppresses the unwrap but
        // carries no justification — that omission is its own finding
        (srv.clone(), 35, "lint-allow".into()),
        ("fixtures/cluster/wire.rs".into(), 3, rules::WIRE_COVERAGE.into()),
        ("fixtures/rng.rs".into(), 6, rules::NO_ENTROPY.into()),
        ("fixtures/rng.rs".into(), 7, rules::NO_ENTROPY.into()),
    ];
    assert_eq!(got, want, "full diagnostic set drifted");
}

#[test]
fn patterns_inside_literals_and_comments_never_fire() {
    let src = r##"
// partial_cmp .unwrap() Instant::now() HashMap in a line comment
/* panic! and /* nested */ SystemTime::now() in a block comment */
fn quiet() -> usize {
    let s = "partial_cmp .unwrap() panic! Instant::now() HashMap";
    let r = r#"from_entropy OsRng .expect( unreachable!"#;
    s.len() + r.len()
}
"##;
    let f = SourceFile::parse("cluster/server.rs", src, false);
    let findings = run(&[f]);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn suppression_requires_matching_rule_and_adjacent_line() {
    // justified allow on the preceding line: suppressed, no residue
    let ok = "\
// lint:allow(no-wallclock-in-deterministic-paths) wall telemetry only\n\
fn f() { let t = Instant::now(); let _ = t; }\n";
    let f = SourceFile::parse("cluster/service/x.rs", ok, false);
    assert!(run(&[f]).is_empty());

    // an allow for a *different* rule does not suppress
    let wrong_rule = "\
// lint:allow(no-panic-in-server-loops) wrong rule on purpose\n\
fn f() { let t = Instant::now(); let _ = t; }\n";
    let f = SourceFile::parse("cluster/service/x.rs", wrong_rule, false);
    let got = run(&[f]);
    assert_eq!(got.len(), 1, "{got:?}");
    assert_eq!(got[0].rule, rules::NO_WALLCLOCK);

    // an allow two lines above the site does not suppress
    let too_far = "\
// lint:allow(no-wallclock-in-deterministic-paths) stranded allow\n\
fn f() {\n\
    let t = Instant::now();\n\
    let _ = t;\n\
}\n";
    let f = SourceFile::parse("cluster/service/x.rs", too_far, false);
    let got = run(&[f]);
    assert!(
        got.iter().any(|fd| fd.rule == rules::NO_WALLCLOCK && fd.line == 3),
        "{got:?}"
    );

    // unknown rule names are flagged, never silently ignored
    let unknown = "fn f() {} // lint:allow(no-such-rule) typo\n";
    let f = SourceFile::parse("anywhere.rs", unknown, false);
    let got = run(&[f]);
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].rule, "lint-allow");
}

#[test]
fn output_is_stable_sorted_and_deduped() {
    let files = || {
        vec![
            fixture("rng.rs"),
            fixture("cluster/wire.rs"),
            fixture("cluster/server.rs"),
            fixture("cluster/transport.rs"),
        ]
    };
    let a = run(&files());
    let b = run(&files());
    assert_eq!(a, b, "two runs over the same inputs must agree exactly");
    let mut sorted = a.clone();
    sorted.sort();
    sorted.dedup();
    assert_eq!(a, sorted, "output must arrive (path, line, rule)-sorted");
}

#[test]
fn wire_coverage_sees_tests_in_sibling_test_files() {
    let wire = "\
pub enum Msg {\n\
    Hello { agent: String },\n\
    Shutdown,\n\
}\n";
    // an integration-test file (all_test = true) covering both
    // variants clears the finding the fixture version raises
    let its = "\
fn roundtrip() {\n\
    let _ = Msg::Hello { agent: String::new() };\n\
    let _ = Msg::Shutdown;\n\
}\n";
    let covered = vec![
        SourceFile::parse("cluster/wire.rs", wire, false),
        SourceFile::parse("tests/wire_roundtrip.rs", its, true),
    ];
    assert!(run(&covered).is_empty());

    // without the test file, both variants are uncovered
    let bare = vec![SourceFile::parse("cluster/wire.rs", wire, false)];
    let got = run(&bare);
    assert_eq!(got.len(), 2, "{got:?}");
    assert!(got.iter().all(|f| f.rule == rules::WIRE_COVERAGE && f.line == 1));
    assert!(got.iter().any(|f| f.message.contains("Msg::Hello")));
    assert!(got.iter().any(|f| f.message.contains("Msg::Shutdown")));

    // non-test references never count as coverage
    let live_use = vec![
        SourceFile::parse("cluster/wire.rs", wire, false),
        SourceFile::parse(
            "cluster/server.rs",
            "fn f() { let _ = Msg::Shutdown; }\n",
            false,
        ),
    ];
    let got = run(&live_use);
    assert_eq!(got.len(), 2, "live code must not satisfy coverage: {got:?}");
}

#[test]
fn test_context_files_are_exempt_from_code_rules() {
    // unwraps and clocks inside an integration test are fine even
    // under a cluster/ path-shaped name
    let src = "fn t() { let x = vec![1].pop().unwrap(); let _ = (x, Instant::now()); }\n";
    let f = SourceFile::parse("rust/tests/cluster_resilience.rs", src, true);
    assert!(run(&[f]).is_empty());
}
