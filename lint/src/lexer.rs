//! A hand-rolled Rust lexer: just enough tokenization that rule
//! patterns can never fire inside comments, string/char literals, or
//! raw strings — the failure mode that makes grep-based linting
//! useless on this codebase (e.g. `partition/classify.rs` documents
//! the PR 5 `partial_cmp` bug *in a comment*).
//!
//! The lexer is deliberately lossy where rules don't care: literal
//! *contents* are discarded (only the fact that a literal occupies
//! those lines survives), and multi-character operators arrive as
//! single-character [`TokenKind::Punct`] tokens (`::` is two `:`
//! tokens). Rules match token sequences, so neither loss matters.

/// Classification of one token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokenKind {
    /// Identifier or keyword (`unwrap`, `fn`, `HashMap`, …).
    Ident,
    /// One punctuation character (`.`, `:`, `!`, `{`, …).
    Punct,
    /// String/char/byte/raw-string/numeric literal, contents elided.
    Literal,
    /// A lifetime (`'a`, `'static`) — distinguished from char literals.
    Lifetime,
}

/// One token with the 1-based line it starts on.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokenKind,
    pub text: String,
    pub line: u32,
}

/// A `// lint:allow(<rule>) <justification>` suppression comment.
#[derive(Clone, Debug)]
pub struct Directive {
    /// Line the comment sits on.
    pub line: u32,
    /// Rule name between the parentheses.
    pub rule: String,
    /// Whether a non-empty justification follows the closing paren.
    pub justified: bool,
    /// True when code precedes the comment on the same line (the
    /// trailing form, which suppresses its own line); false for a
    /// standalone comment line (which suppresses the next code line).
    pub trailing: bool,
}

/// The token stream and suppression directives of one source file.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub directives: Vec<Directive>,
}

/// Tokenize `src`. Never fails: unrecognized bytes are skipped, and
/// unterminated literals simply run to end of file.
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut line_has_code = false;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                line_has_code = false;
                i += 1;
            }
            b' ' | b'\t' | b'\r' => i += 1,
            b'/' if peek(b, i + 1) == Some(b'/') => {
                // line comment (incl. doc comments); may carry a directive
                let start = i + 2;
                let mut j = start;
                while j < b.len() && b[j] != b'\n' {
                    j += 1;
                }
                if let Some(d) = parse_directive(&src[start..j], line, line_has_code) {
                    out.directives.push(d);
                }
                i = j;
            }
            b'/' if peek(b, i + 1) == Some(b'*') => {
                // block comment, nesting-aware
                let mut depth = 1u32;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && peek(b, i + 1) == Some(b'*') {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && peek(b, i + 1) == Some(b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            b'"' => {
                let at = line;
                i = scan_string(b, i, &mut line);
                out.tokens.push(Token {
                    kind: TokenKind::Literal,
                    text: String::from("\"\""),
                    line: at,
                });
                line_has_code = true;
            }
            b'\'' => {
                // lifetime (`'a` not closed by a quote) vs char literal
                let n1 = peek(b, i + 1);
                let n2 = peek(b, i + 2);
                let is_lifetime = matches!(n1, Some(x) if x == b'_' || x.is_ascii_alphabetic())
                    && n2 != Some(b'\'');
                if is_lifetime {
                    let s = i + 1;
                    let mut j = s;
                    while j < b.len() && (b[j] == b'_' || b[j].is_ascii_alphanumeric()) {
                        j += 1;
                    }
                    out.tokens.push(Token {
                        kind: TokenKind::Lifetime,
                        text: src[s..j].to_string(),
                        line,
                    });
                    i = j;
                } else {
                    i = scan_char(b, i);
                    out.tokens.push(Token {
                        kind: TokenKind::Literal,
                        text: String::from("''"),
                        line,
                    });
                }
                line_has_code = true;
            }
            _ if c == b'_' || c.is_ascii_alphabetic() => {
                // `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`, `b'…'` are
                // literals despite starting with an ident byte
                if let Some((next, at)) = scan_literal_prefix(b, i, &mut line) {
                    out.tokens.push(Token {
                        kind: TokenKind::Literal,
                        text: String::from("\"\""),
                        line: at,
                    });
                    i = next;
                } else {
                    let s = i;
                    let mut j = i;
                    while j < b.len() && (b[j] == b'_' || b[j].is_ascii_alphanumeric()) {
                        j += 1;
                    }
                    out.tokens.push(Token {
                        kind: TokenKind::Ident,
                        text: src[s..j].to_string(),
                        line,
                    });
                    i = j;
                }
                line_has_code = true;
            }
            _ if c.is_ascii_digit() => {
                let s = i;
                let mut j = i + 1;
                while j < b.len() && (b[j] == b'_' || b[j].is_ascii_alphanumeric()) {
                    j += 1;
                }
                // fractional part (`1.5`); `0..n` ranges and tuple
                // fields stop before the dot because no digit follows
                if j + 1 < b.len() && b[j] == b'.' && b[j + 1].is_ascii_digit() {
                    j += 1;
                    while j < b.len() && (b[j] == b'_' || b[j].is_ascii_alphanumeric()) {
                        j += 1;
                    }
                }
                out.tokens.push(Token {
                    kind: TokenKind::Literal,
                    text: src[s..j].to_string(),
                    line,
                });
                i = j;
                line_has_code = true;
            }
            _ => {
                if c.is_ascii() {
                    out.tokens.push(Token {
                        kind: TokenKind::Punct,
                        text: (c as char).to_string(),
                        line,
                    });
                    line_has_code = true;
                }
                // non-ASCII bytes outside literals/comments: skip
                i += 1;
            }
        }
    }
    out
}

fn peek(b: &[u8], i: usize) -> Option<u8> {
    b.get(i).copied()
}

/// From the opening `"` at `i`, return the index just past the closing
/// quote, counting newlines into `line`.
fn scan_string(b: &[u8], mut i: usize, line: &mut u32) -> usize {
    i += 1;
    while i < b.len() {
        match b[i] {
            b'\\' => {
                // an escaped newline (line-continuation) still ends a
                // source line — keep the line counter honest
                if peek(b, i + 1) == Some(b'\n') {
                    *line += 1;
                }
                i += 2;
            }
            b'"' => return i + 1,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// From the opening `'` at `i`, return the index just past the closing
/// quote of a char literal.
fn scan_char(b: &[u8], mut i: usize) -> usize {
    i += 1;
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'\'' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

/// If `i` starts a raw/byte string or byte-char literal (`r"`, `r#"`,
/// `b"`, `br"`, `br#"`, `b'`), scan the whole literal and return
/// `(index_past_literal, start_line)`. Identifiers that merely begin
/// with `r`/`b` (`rows`, `budget`, `break`) return `None`.
fn scan_literal_prefix(b: &[u8], i: usize, line: &mut u32) -> Option<(usize, u32)> {
    let at = *line;
    let mut j = i;
    if peek(b, j) == Some(b'b') {
        j += 1;
    }
    let raw = peek(b, j) == Some(b'r');
    if raw {
        j += 1;
    }
    if j == i {
        return None; // no `b`/`r` prefix at all
    }
    if raw {
        let mut hashes = 0usize;
        while peek(b, j) == Some(b'#') {
            hashes += 1;
            j += 1;
        }
        if peek(b, j) != Some(b'"') {
            return None; // `r`/`br` was just the start of an identifier
        }
        j += 1;
        loop {
            match peek(b, j) {
                None => return Some((j, at)),
                Some(b'\n') => {
                    *line += 1;
                    j += 1;
                }
                Some(b'"') => {
                    let mut k = j + 1;
                    let mut seen = 0usize;
                    while seen < hashes && peek(b, k) == Some(b'#') {
                        seen += 1;
                        k += 1;
                    }
                    if seen == hashes {
                        return Some((k, at));
                    }
                    j += 1;
                }
                Some(_) => j += 1,
            }
        }
    }
    match peek(b, j) {
        Some(b'"') => Some((scan_string(b, j, line), at)),
        Some(b'\'') => Some((scan_char(b, j), at)),
        _ => None, // plain identifier starting with `b`
    }
}

/// Parse a `lint:allow(<rule>) <justification>` directive out of one
/// line comment's text (everything after `//`). Leading `/` from doc
/// comments and whitespace are tolerated.
fn parse_directive(comment: &str, line: u32, trailing: bool) -> Option<Directive> {
    let t = comment.trim_start_matches('/').trim_start();
    let rest = t.strip_prefix("lint:allow(")?;
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let justified = !rest[close + 1..].trim().is_empty();
    Some(Directive {
        line,
        rule,
        justified,
        trailing,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter(|t| t.kind == TokenKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_and_strings_hide_patterns() {
        let src = r##"
// partial_cmp in a comment must not tokenize
/* nested /* block */ partial_cmp */
let s = "calls .unwrap() inside a string";
let r = r#"raw string with Instant::now()"#;
let real = x.unwrap();
"##;
        let ids = idents(src);
        assert!(!ids.contains(&"partial_cmp".to_string()), "{ids:?}");
        assert!(!ids.contains(&"Instant".to_string()), "{ids:?}");
        assert_eq!(ids.iter().filter(|t| *t == "unwrap").count(), 1);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> char { 'x' }";
        let toks = lex(src).tokens;
        assert!(toks
            .iter()
            .any(|t| t.kind == TokenKind::Lifetime && t.text == "a"));
        // the char literal 'x' must not swallow the closing brace
        assert!(toks.iter().any(|t| t.kind == TokenKind::Punct && t.text == "}"));
        // and `str`/`char` still tokenize after the lifetime
        let ids = idents(src);
        assert!(ids.contains(&"str".to_string()) && ids.contains(&"char".to_string()));
    }

    #[test]
    fn raw_string_hashes_must_match_to_close() {
        let src = r###"let s = r##"inner "# quote .unwrap() "##; after()"###;
        let ids = idents(src);
        assert!(!ids.contains(&"unwrap".to_string()), "{ids:?}");
        assert!(ids.contains(&"after".to_string()), "{ids:?}");
    }

    #[test]
    fn identifiers_starting_with_r_and_b_survive() {
        let ids = idents("let rows = budget + break_even - r2d2;");
        for want in ["rows", "budget", "break_even", "r2d2"] {
            assert!(ids.contains(&want.to_string()), "{ids:?}");
        }
    }

    #[test]
    fn directives_parse_with_and_without_justification() {
        let src = "\
// lint:allow(no-wallclock-in-deterministic-paths) telemetry only\n\
let t = now();\n\
let u = later(); // lint:allow(no-panic-in-server-loops)\n";
        let lexed = lex(src);
        assert_eq!(lexed.directives.len(), 2);
        let d0 = &lexed.directives[0];
        assert_eq!(d0.line, 1);
        assert_eq!(d0.rule, "no-wallclock-in-deterministic-paths");
        assert!(d0.justified && !d0.trailing);
        let d1 = &lexed.directives[1];
        assert_eq!(d1.line, 3);
        assert!(!d1.justified);
        assert!(d1.trailing);
    }

    #[test]
    fn lines_advance_through_multiline_literals_and_comments() {
        let src = "let a = \"line\none\";\n/* two\nlines */\nlet b = 1;";
        let toks = lex(src).tokens;
        let b_tok = toks.iter().find(|t| t.text == "b").expect("b token");
        assert_eq!(b_tok.line, 5);
    }
}
