//! `uepmm-lint` — repo-specific static analysis for the determinism
//! invariants the UEP cluster lives on: bit-identical decodes across
//! runs, thread counts, transports, and client interleavings.
//!
//! The pipeline is [`lexer`] (a string/char/comment/raw-string-aware
//! Rust tokenizer, so rule patterns can never fire inside literals or
//! comments) → [`rules`] (the repo-specific catalog) → [`engine`]
//! (test-region detection, `lint:allow` suppression, stable sorted
//! diagnostics). Dependency-free by design: it must build in the
//! offline container next to the crate it analyzes.
//!
//! Run it as CI does: `cargo run -p uepmm-lint -- rust/src`.

pub mod engine;
pub mod lexer;
pub mod rules;
