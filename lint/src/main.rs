//! CLI: `uepmm-lint <file-or-dir>...` — lex every `.rs` file under the
//! given roots, run the rule catalog, print `(path, line, rule)`-sorted
//! diagnostics, and exit non-zero on any undiagnosed finding.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use uepmm_lint::engine::{self, SourceFile};
use uepmm_lint::rules;

fn main() -> ExitCode {
    let roots: Vec<String> = std::env::args().skip(1).collect();
    if roots.is_empty() {
        eprintln!("usage: uepmm-lint <file-or-dir>...");
        return ExitCode::from(2);
    }
    let mut files: Vec<SourceFile> = Vec::new();
    for root in &roots {
        let root_path = PathBuf::from(root);
        let mut code = Vec::new();
        if let Err(e) = collect(&root_path, &mut code) {
            eprintln!("uepmm-lint: {root}: {e}");
            return ExitCode::from(2);
        }
        // Pointed at a crate's `src/`, pull in the sibling `tests/`
        // directory as test-only context: cross-file coverage rules
        // need to *see* integration tests without linting them.
        let mut test_ctx = Vec::new();
        let sibling_tests = (root_path.file_name().and_then(|n| n.to_str()) == Some("src"))
            .then(|| root_path.parent().map(|p| p.join("tests")))
            .flatten()
            .filter(|t| t.is_dir());
        if let Some(tests) = sibling_tests {
            if let Err(e) = collect(&tests, &mut test_ctx) {
                eprintln!("uepmm-lint: {}: {e}", tests.display());
                return ExitCode::from(2);
            }
        }
        for (list, forced_test) in [(&code, false), (&test_ctx, true)] {
            for p in list.iter() {
                let src = match std::fs::read_to_string(p) {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("uepmm-lint: {}: {e}", p.display());
                        return ExitCode::from(2);
                    }
                };
                let shown = p.to_string_lossy().replace('\\', "/");
                let all_test =
                    forced_test || shown.contains("/tests/") || shown.starts_with("tests/");
                files.push(SourceFile::parse(&shown, &src, all_test));
            }
        }
    }
    let findings = engine::run(&files);
    for fd in &findings {
        println!("{}:{}: [{}] {}", fd.path, fd.line, fd.rule, fd.message);
    }
    if findings.is_empty() {
        println!(
            "uepmm-lint: clean — {} files, {} rules",
            files.len(),
            rules::RULES.len()
        );
        ExitCode::SUCCESS
    } else {
        eprintln!("uepmm-lint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}

/// Gather `.rs` files under `path` (or `path` itself), sorted for
/// deterministic scan order; `target/` and dotdirs are skipped.
fn collect(path: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if path.is_file() {
        out.push(path.to_path_buf());
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = std::fs::read_dir(path)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .collect();
    entries.sort();
    for p in entries {
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if p.is_dir() {
            if name == "target" || name.starts_with('.') {
                continue;
            }
            collect(&p, out)?;
        } else if name.ends_with(".rs") {
            out.push(p);
        }
    }
    Ok(())
}
