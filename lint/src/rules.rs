//! The rule catalog. Every rule here encodes an invariant this repo
//! has already been burned by (or nearly so) — see CONTRIBUTING.md
//! ("Invariants and the lint") for the rationale-per-rule.

use crate::engine::{is_ident, is_punct, is_seq, skip_balanced, Finding, SourceFile};
use crate::lexer::TokenKind;

pub const NO_PARTIAL_CMP: &str = "no-partial-cmp-sort";
pub const NO_WALLCLOCK: &str = "no-wallclock-in-deterministic-paths";
pub const NO_UNORDERED: &str = "no-unordered-iteration";
pub const NO_PANIC: &str = "no-panic-in-server-loops";
pub const NO_ENTROPY: &str = "no-ambient-entropy";
pub const WIRE_COVERAGE: &str = "wire-frame-test-coverage";

/// Every rule name, for directive validation and the CLI banner.
pub const RULES: &[&str] = &[
    NO_PARTIAL_CMP,
    NO_WALLCLOCK,
    NO_UNORDERED,
    NO_PANIC,
    NO_ENTROPY,
    WIRE_COVERAGE,
];

pub fn is_known_rule(rule: &str) -> bool {
    RULES.contains(&rule)
}

/// Files where wall-clock reads are legitimate by construction:
/// Wall-mode transport deadlines, the CLI, and benches. Everything
/// else needs a per-site `lint:allow` explaining why the read cannot
/// reach decode/dispatch state.
fn wallclock_exempt(path: &str) -> bool {
    path.ends_with("cluster/transport.rs")
        || path.ends_with("src/main.rs")
        || path.contains("benches/")
}

/// Paths whose map iteration order can reach dispatch/decode outcomes.
fn unordered_scope(path: &str) -> bool {
    path.contains("cluster/") || path.contains("coordinator/") || path.contains("api/")
}

/// Long-running server-loop files where a panic kills a multi-tenant
/// plane or a worker fleet member. Scoped to whole non-test files (a
/// superset of the literal loop bodies): helpers called from the loops
/// panic the same threads.
fn panic_scope(path: &str) -> bool {
    path.ends_with("cluster/server.rs")
        || path.ends_with("cluster/worker.rs")
        || path.contains("cluster/service/")
}

/// All single-file rules over one source file.
pub fn check_file(f: &SourceFile, out: &mut Vec<Finding>) {
    let toks = &f.tokens;
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokenKind::Ident {
            continue;
        }
        let line = t.line;
        let in_test = f.is_test_line(line);
        match t.text.as_str() {
            // flagged in tests too: a NaN-panicking comparator in a
            // test is a flake of exactly the PR 5 class
            "partial_cmp" => out.push(finding(
                f,
                line,
                NO_PARTIAL_CMP,
                "float comparison via `partial_cmp` panics on NaN in sort paths; use `total_cmp`",
            )),
            // also flagged in tests: seeded Pcg64 everywhere is what
            // makes the bit-identity assertions meaningful
            "from_entropy" | "thread_rng" | "OsRng" | "getrandom" => out.push(finding(
                f,
                line,
                NO_ENTROPY,
                "ambient OS entropy breaks reproducibility; draw from a seeded `Pcg64`",
            )),
            "Instant" | "SystemTime" if !in_test && !wallclock_exempt(&f.path) => {
                if is_seq(toks, i + 1, &[":", ":"])
                    && toks.get(i + 3).is_some_and(|n| is_ident(n, "now"))
                {
                    out.push(finding(
                        f,
                        line,
                        NO_WALLCLOCK,
                        &format!(
                            "`{}::now()` reads the wall clock near deterministic paths; route \
                             through virtual time, or lint:allow with why it cannot reach \
                             decode state",
                            t.text
                        ),
                    ));
                }
            }
            "HashMap" | "HashSet" if !in_test && unordered_scope(&f.path) => {
                out.push(finding(
                    f,
                    line,
                    NO_UNORDERED,
                    &format!(
                        "`{}` iteration order varies per process in dispatch/decode paths; \
                         use `BTree{}` or sort before iterating",
                        t.text,
                        &t.text[4..]
                    ),
                ));
            }
            "unwrap" | "expect"
                if !in_test
                    && panic_scope(&f.path)
                    && i > 0
                    && is_punct(&toks[i - 1], ".")
                    && toks.get(i + 1).is_some_and(|n| is_punct(n, "(")) =>
            {
                out.push(finding(
                    f,
                    line,
                    NO_PANIC,
                    &format!(
                        "`.{}(..)` can panic a long-running server loop; propagate a typed \
                         error instead",
                        t.text
                    ),
                ));
            }
            "panic" | "unreachable" | "todo" | "unimplemented"
                if !in_test
                    && panic_scope(&f.path)
                    && toks.get(i + 1).is_some_and(|n| is_punct(n, "!")) =>
            {
                out.push(finding(
                    f,
                    line,
                    NO_PANIC,
                    &format!(
                        "`{}!` takes down a long-running server loop; degrade gracefully \
                         instead",
                        t.text
                    ),
                ));
            }
            _ => {}
        }
    }
}

/// Cross-file rule: every variant of `enum Msg` in `cluster/wire.rs`
/// must appear as `Msg::<Variant>` somewhere in test code (the wire
/// round-trip tests, or an integration test under `tests/`).
pub fn check_cross_file(files: &[SourceFile], out: &mut Vec<Finding>) {
    for wire in files.iter().filter(|f| f.path.ends_with("cluster/wire.rs")) {
        let Some((enum_line, variants)) = msg_variants(wire) else {
            continue;
        };
        for v in &variants {
            if !files.iter().any(|f| covers_variant(f, v)) {
                out.push(Finding {
                    path: wire.path.clone(),
                    line: enum_line,
                    rule: WIRE_COVERAGE.to_string(),
                    message: format!(
                        "wire frame `Msg::{v}` never appears in a test; add it to the \
                         round-trip coverage"
                    ),
                });
            }
        }
    }
}

/// Parse the variant names of `enum Msg { … }` from a lexed wire.rs.
/// Returns the line of the `enum` keyword and the names in order.
fn msg_variants(f: &SourceFile) -> Option<(u32, Vec<String>)> {
    let t = &f.tokens;
    let mut i = 0usize;
    while i + 1 < t.len() {
        if !(is_ident(&t[i], "enum") && is_ident(&t[i + 1], "Msg")) {
            i += 1;
            continue;
        }
        let enum_line = t[i].line;
        let mut j = i + 2;
        while j < t.len() && !is_punct(&t[j], "{") {
            j += 1;
        }
        if j >= t.len() {
            return None;
        }
        let mut vars = Vec::new();
        let mut depth = 1u32;
        let mut expect_name = true;
        let mut k = j + 1;
        while k < t.len() && depth > 0 {
            let tok = &t[k];
            if tok.kind == TokenKind::Punct {
                match tok.text.as_str() {
                    "{" | "(" | "[" => depth += 1,
                    "}" | ")" | "]" => depth = depth.saturating_sub(1),
                    "," if depth == 1 => expect_name = true,
                    "#" if depth == 1 => {
                        // variant attribute: skip the whole `[...]`
                        k = skip_balanced(t, k + 1, "[", "]");
                        continue;
                    }
                    _ => {}
                }
            } else if tok.kind == TokenKind::Ident && depth == 1 && expect_name {
                vars.push(tok.text.clone());
                expect_name = false;
            }
            k += 1;
        }
        return Some((enum_line, vars));
    }
    None
}

/// Does `f` reference `Msg::<variant>` on a test line?
fn covers_variant(f: &SourceFile, variant: &str) -> bool {
    let toks = &f.tokens;
    (0..toks.len()).any(|i| {
        is_ident(&toks[i], "Msg")
            && f.is_test_line(toks[i].line)
            && is_seq(toks, i + 1, &[":", ":"])
            && toks.get(i + 3).is_some_and(|t| is_ident(t, variant))
    })
}

fn finding(f: &SourceFile, line: u32, rule: &str, msg: &str) -> Finding {
    Finding {
        path: f.path.clone(),
        line,
        rule: rule.to_string(),
        message: msg.to_string(),
    }
}
