//! The rule engine: per-file token streams, `#[cfg(test)]` region
//! detection (token-level brace matching), suppression resolution, and
//! stable `(path, line, rule)`-sorted diagnostics.

use crate::lexer::{lex, Directive, Token, TokenKind};

/// One lexed source file plus the derived test-region map.
pub struct SourceFile {
    /// Path with `/` separators, as reported in diagnostics.
    pub path: String,
    pub tokens: Vec<Token>,
    pub directives: Vec<Directive>,
    /// Closed line ranges covered by `#[cfg(test)]` items.
    test_regions: Vec<(u32, u32)>,
    /// Whole file is test context (integration tests under `tests/`).
    all_test: bool,
}

impl SourceFile {
    pub fn parse(path: &str, src: &str, all_test: bool) -> SourceFile {
        let lexed = lex(src);
        let test_regions = find_test_regions(&lexed.tokens);
        SourceFile {
            path: path.replace('\\', "/"),
            tokens: lexed.tokens,
            directives: lexed.directives,
            test_regions,
            all_test,
        }
    }

    /// Is `line` inside test-only code? Most rules skip such lines;
    /// the cross-file coverage rule *searches* them.
    pub fn is_test_line(&self, line: u32) -> bool {
        self.all_test || self.test_regions.iter().any(|&(a, z)| (a..=z).contains(&line))
    }
}

/// One diagnostic. The derived `Ord` is the output order: path, then
/// line, then rule, then message — stable across runs by construction.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub path: String,
    pub line: u32,
    pub rule: String,
    pub message: String,
}

pub fn is_punct(t: &Token, s: &str) -> bool {
    t.kind == TokenKind::Punct && t.text == s
}

pub fn is_ident(t: &Token, s: &str) -> bool {
    t.kind == TokenKind::Ident && t.text == s
}

/// Do the tokens at `at..` match this exact sequence of punctuation?
pub fn is_seq(toks: &[Token], at: usize, puncts: &[&str]) -> bool {
    puncts
        .iter()
        .enumerate()
        .all(|(k, p)| toks.get(at + k).is_some_and(|t| is_punct(t, p)))
}

/// From `at` (pointing at an `open` punct), return the index just past
/// the matching `close`, or `toks.len()` on imbalance.
pub fn skip_balanced(toks: &[Token], at: usize, open: &str, close: &str) -> usize {
    let mut depth = 0usize;
    let mut i = at;
    while i < toks.len() {
        if is_punct(&toks[i], open) {
            depth += 1;
        } else if is_punct(&toks[i], close) {
            depth = depth.saturating_sub(1);
            if depth == 0 {
                return i + 1;
            }
        }
        i += 1;
    }
    toks.len()
}

/// Find every `#[cfg(test)]` item and brace-match its body to a line
/// range. Brace matching is token-level, so braces inside strings or
/// comments cannot desynchronize it.
fn find_test_regions(toks: &[Token]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 6 < toks.len() {
        let hit = is_punct(&toks[i], "#")
            && is_punct(&toks[i + 1], "[")
            && is_ident(&toks[i + 2], "cfg")
            && is_punct(&toks[i + 3], "(")
            && is_ident(&toks[i + 4], "test")
            && is_punct(&toks[i + 5], ")")
            && is_punct(&toks[i + 6], "]");
        if !hit {
            i += 1;
            continue;
        }
        let start = toks[i].line;
        // skip further attributes stacked on the same item
        let mut j = i + 7;
        while j + 1 < toks.len() && is_punct(&toks[j], "#") && is_punct(&toks[j + 1], "[") {
            j = skip_balanced(toks, j + 1, "[", "]");
        }
        // the item ends at its braced body, or at a bare `;`
        let mut end = toks.last().map(|t| t.line).unwrap_or(start);
        let mut k = j;
        while k < toks.len() {
            if is_punct(&toks[k], ";") {
                end = toks[k].line;
                break;
            }
            if is_punct(&toks[k], "{") {
                let past = skip_balanced(toks, k, "{", "}");
                end = toks[past.saturating_sub(1).min(toks.len() - 1)].line;
                break;
            }
            k += 1;
        }
        out.push((start, end));
        i = j;
    }
    out
}

/// Run every rule over `files`, validate and apply `lint:allow`
/// directives, and return the surviving findings sorted and deduped.
pub fn run(files: &[SourceFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    for f in files {
        crate::rules::check_file(f, &mut findings);
    }
    crate::rules::check_cross_file(files, &mut findings);
    // malformed directives are findings themselves — a suppression
    // without a reason is exactly the hand-audit rot the lint replaces
    for f in files {
        for d in &f.directives {
            if !crate::rules::is_known_rule(&d.rule) {
                findings.push(Finding {
                    path: f.path.clone(),
                    line: d.line,
                    rule: "lint-allow".to_string(),
                    message: format!("lint:allow names unknown rule `{}`", d.rule),
                });
            } else if !d.justified {
                findings.push(Finding {
                    path: f.path.clone(),
                    line: d.line,
                    rule: "lint-allow".to_string(),
                    message: format!(
                        "lint:allow({}) needs a one-line justification after the `)`",
                        d.rule
                    ),
                });
            }
        }
    }
    let mut kept: Vec<Finding> = findings
        .into_iter()
        .filter(|fd| !suppressed(files, fd))
        .collect();
    kept.sort();
    kept.dedup();
    kept
}

fn suppressed(files: &[SourceFile], fd: &Finding) -> bool {
    if fd.rule == "lint-allow" {
        return false; // directive hygiene findings cannot be allowed away
    }
    let Some(f) = files.iter().find(|f| f.path == fd.path) else {
        return false;
    };
    f.directives
        .iter()
        .any(|d| d.rule == fd.rule && directive_target(f, d) == fd.line)
}

/// The line a directive covers: its own line for the trailing form,
/// else the next line holding any token (stacked standalone directives
/// above one statement therefore all target that statement).
fn directive_target(f: &SourceFile, d: &Directive) -> u32 {
    if d.trailing {
        return d.line;
    }
    f.tokens
        .iter()
        .map(|t| t.line)
        .find(|&l| l > d.line)
        .unwrap_or(d.line)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_test_region_spans_the_braced_mod() {
        let src = "\
fn live() {}\n\
#[cfg(test)]\n\
mod tests {\n\
    fn helper() { let x = \"}\"; }\n\
    #[test]\n\
    fn t() {}\n\
}\n\
fn also_live() {}\n";
        let f = SourceFile::parse("x.rs", src, false);
        assert!(!f.is_test_line(1));
        assert!(f.is_test_line(2));
        assert!(f.is_test_line(4), "brace inside string must not close the mod");
        assert!(f.is_test_line(7));
        assert!(!f.is_test_line(8));
    }

    #[test]
    fn stacked_attributes_still_find_the_body() {
        let src = "\
#[cfg(test)]\n\
#[allow(dead_code)]\n\
mod tests {\n\
    fn t() {}\n\
}\n";
        let f = SourceFile::parse("x.rs", src, false);
        assert!(f.is_test_line(4));
    }

    #[test]
    fn tests_dir_files_are_all_test() {
        let f = SourceFile::parse("rust/tests/it.rs", "fn x() {}", true);
        assert!(f.is_test_line(1));
    }
}
